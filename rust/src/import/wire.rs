//! Minimal protobuf wire-format reader/writer.
//!
//! ONNX models only use a handful of the protobuf wire types: varint
//! (field numbers, int64/enum values), length-delimited (strings, bytes,
//! nested messages, packed repeated scalars), and the two fixed-width
//! forms (float / double). This module implements exactly that subset,
//! with no code generation and no dependencies: [`Reader`] walks a byte
//! slice and reports malformed data as [`ImportError::Wire`] carrying
//! the *absolute* byte offset (nested readers remember their base), and
//! [`Writer`] emits the same subset for the exporter.

use super::error::ImportError;

/// Protobuf wire types (the 3-bit tag suffix).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireType {
    /// Wire type 0: base-128 varint.
    Varint,
    /// Wire type 1: little-endian 64-bit.
    Fixed64,
    /// Wire type 2: length-delimited (bytes, strings, messages, packed).
    Len,
    /// Wire type 5: little-endian 32-bit.
    Fixed32,
}

/// Streaming reader over one protobuf message body.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
    /// Absolute offset of `buf[0]` in the original model file, so nested
    /// message readers report errors at file positions, not local ones.
    base: usize,
}

impl<'a> Reader<'a> {
    /// Reader over a whole buffer (base offset 0).
    pub fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0, base: 0 }
    }

    /// True when the message body is fully consumed.
    pub fn at_end(&self) -> bool {
        self.pos >= self.buf.len()
    }

    /// Absolute byte offset of the read cursor.
    pub fn offset(&self) -> usize {
        self.base + self.pos
    }

    fn err(&self, detail: impl Into<String>) -> ImportError {
        ImportError::wire(self.offset(), detail)
    }

    /// Decode one base-128 varint.
    pub fn varint(&mut self) -> Result<u64, ImportError> {
        let start = self.offset();
        let mut out: u64 = 0;
        for i in 0..10 {
            let Some(&b) = self.buf.get(self.pos) else {
                return Err(ImportError::wire(start, "truncated varint"));
            };
            self.pos += 1;
            // the 10th byte of a u64 varint may only carry the top bit
            if i == 9 && b > 1 {
                return Err(ImportError::wire(start, "varint overflows 64 bits"));
            }
            out |= u64::from(b & 0x7f) << (7 * i);
            if b & 0x80 == 0 {
                return Ok(out);
            }
        }
        Err(ImportError::wire(start, "varint longer than 10 bytes"))
    }

    /// Decode a field tag into `(field_number, wire_type)`.
    ///
    /// Rejects field number 0 and the wire types protobuf has deprecated
    /// or never assigned (groups 3/4, codes 6/7) — ONNX uses neither.
    pub fn tag(&mut self) -> Result<(u32, WireType), ImportError> {
        let start = self.offset();
        let key = self.varint()?;
        let field = (key >> 3) as u32;
        if field == 0 {
            return Err(ImportError::wire(start, "field number 0"));
        }
        let wt = match key & 7 {
            0 => WireType::Varint,
            1 => WireType::Fixed64,
            2 => WireType::Len,
            5 => WireType::Fixed32,
            w => {
                return Err(ImportError::wire(
                    start,
                    format!("unsupported wire type {w} (field {field})"),
                ))
            }
        };
        Ok((field, wt))
    }

    /// Read a length-delimited payload.
    pub fn bytes(&mut self) -> Result<&'a [u8], ImportError> {
        let start = self.offset();
        let len = self.varint()? as usize;
        if len > self.buf.len() - self.pos {
            return Err(ImportError::wire(
                start,
                format!("length {len} exceeds remaining {} bytes", self.buf.len() - self.pos),
            ));
        }
        let out = &self.buf[self.pos..self.pos + len];
        self.pos += len;
        Ok(out)
    }

    /// Read a length-delimited payload as UTF-8 (lossy for robustness —
    /// names in the wild occasionally carry stray bytes).
    pub fn string(&mut self) -> Result<String, ImportError> {
        Ok(String::from_utf8_lossy(self.bytes()?).into_owned())
    }

    /// Read a nested message: a length-delimited payload wrapped in a
    /// [`Reader`] that keeps reporting absolute offsets.
    pub fn msg(&mut self) -> Result<Reader<'a>, ImportError> {
        let abs = self.base + self.pos;
        let len_start = self.pos;
        let body = self.bytes()?;
        // base of the nested body = where the payload starts
        let header = self.pos - len_start - body.len();
        Ok(Reader { buf: body, pos: 0, base: abs + header })
    }

    /// Read a little-endian 32-bit word.
    pub fn fixed32(&mut self) -> Result<u32, ImportError> {
        if self.buf.len() - self.pos < 4 {
            return Err(self.err("truncated fixed32"));
        }
        let b = &self.buf[self.pos..self.pos + 4];
        self.pos += 4;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// Read a little-endian 64-bit word.
    pub fn fixed64(&mut self) -> Result<u64, ImportError> {
        if self.buf.len() - self.pos < 8 {
            return Err(self.err("truncated fixed64"));
        }
        let b = &self.buf[self.pos..self.pos + 8];
        self.pos += 8;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    /// Skip one field value of the given wire type.
    pub fn skip(&mut self, wt: WireType) -> Result<(), ImportError> {
        match wt {
            WireType::Varint => {
                self.varint()?;
            }
            WireType::Fixed64 => {
                self.fixed64()?;
            }
            WireType::Len => {
                self.bytes()?;
            }
            WireType::Fixed32 => {
                self.fixed32()?;
            }
        }
        Ok(())
    }

    /// Decode a repeated-int64 field value: either one varint (unpacked)
    /// or a packed length-delimited run, appended to `out`.
    pub fn int64s(&mut self, wt: WireType, out: &mut Vec<i64>) -> Result<(), ImportError> {
        match wt {
            WireType::Varint => out.push(self.varint()? as i64),
            WireType::Len => {
                let mut inner = self.msg()?;
                while !inner.at_end() {
                    out.push(inner.varint()? as i64);
                }
            }
            _ => return Err(self.err("repeated int64 field with fixed-width wire type")),
        }
        Ok(())
    }

    /// Decode a repeated-float field value (unpacked fixed32 or packed),
    /// appended to `out`.
    pub fn floats(&mut self, wt: WireType, out: &mut Vec<f32>) -> Result<(), ImportError> {
        match wt {
            WireType::Fixed32 => out.push(f32::from_bits(self.fixed32()?)),
            WireType::Len => {
                let mut inner = self.msg()?;
                while !inner.at_end() {
                    out.push(f32::from_bits(inner.fixed32()?));
                }
            }
            _ => return Err(self.err("repeated float field with varint wire type")),
        }
        Ok(())
    }
}

/// Append-only protobuf writer (the exporter's byte sink).
#[derive(Default)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    /// An empty writer.
    pub fn new() -> Self {
        Writer::default()
    }

    /// The bytes written so far.
    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    /// Emit a raw varint.
    pub fn varint(&mut self, mut v: u64) {
        loop {
            let b = (v & 0x7f) as u8;
            v >>= 7;
            if v == 0 {
                self.buf.push(b);
                break;
            }
            self.buf.push(b | 0x80);
        }
    }

    fn tag(&mut self, field: u32, wire: u64) {
        self.varint((u64::from(field) << 3) | wire);
    }

    /// Emit an int64/int32/enum field (standard two's-complement varint).
    pub fn int(&mut self, field: u32, v: i64) {
        self.tag(field, 0);
        self.varint(v as u64);
    }

    /// Emit a length-delimited bytes field.
    pub fn bytes(&mut self, field: u32, v: &[u8]) {
        self.tag(field, 2);
        self.varint(v.len() as u64);
        self.buf.extend_from_slice(v);
    }

    /// Emit a string field.
    pub fn string(&mut self, field: u32, v: &str) {
        self.bytes(field, v.as_bytes());
    }

    /// Emit a nested message field from another writer's bytes.
    pub fn message(&mut self, field: u32, inner: Writer) {
        self.bytes(field, &inner.buf);
    }

    /// Emit a 32-bit float field (wire type 5).
    pub fn float(&mut self, field: u32, v: f32) {
        self.tag(field, 5);
        self.buf.extend_from_slice(&v.to_bits().to_le_bytes());
    }

    /// Emit a packed repeated-int64 field.
    pub fn packed_int64s(&mut self, field: u32, vs: &[i64]) {
        let mut inner = Writer::new();
        for &v in vs {
            inner.varint(v as u64);
        }
        self.bytes(field, &inner.buf);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip_varint(v: u64) {
        let mut w = Writer::new();
        w.varint(v);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        assert_eq!(r.varint().unwrap(), v);
        assert!(r.at_end());
    }

    #[test]
    fn varint_round_trips() {
        for v in [0u64, 1, 127, 128, 300, 16383, 16384, u32::MAX as u64, u64::MAX] {
            round_trip_varint(v);
        }
        // negative int64s encode as 10-byte varints
        let mut w = Writer::new();
        w.int(3, -1);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        let (field, wt) = r.tag().unwrap();
        assert_eq!((field, wt), (3, WireType::Varint));
        assert_eq!(r.varint().unwrap() as i64, -1);
    }

    #[test]
    fn truncated_varint_is_typed_error() {
        let mut r = Reader::new(&[0x80]);
        let e = r.varint().unwrap_err();
        assert!(matches!(e, ImportError::Wire { offset: 0, .. }), "{e}");
    }

    #[test]
    fn bad_tags_are_rejected() {
        // field number 0
        let mut r = Reader::new(&[0x00]);
        assert!(r.tag().is_err());
        // wire type 3 (group start)
        let mut r = Reader::new(&[0x0b]);
        assert!(r.tag().is_err());
    }

    #[test]
    fn overlong_length_is_rejected() {
        // tag field1/len, length 100, only 1 byte of payload
        let mut r = Reader::new(&[0x0a, 100, 0]);
        let (_, wt) = r.tag().unwrap();
        assert_eq!(wt, WireType::Len);
        assert!(r.bytes().is_err());
    }

    #[test]
    fn nested_offsets_are_absolute() {
        // outer: field 1 = message [ field 2 = truncated varint ]
        let mut inner = Writer::new();
        inner.tag(2, 0);
        let mut inner_bytes = inner.into_bytes();
        inner_bytes.push(0x80); // truncated varint payload
        let mut w = Writer::new();
        w.bytes(1, &inner_bytes);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        let _ = r.tag().unwrap();
        let mut m = r.msg().unwrap();
        let _ = m.tag().unwrap();
        let e = m.varint().unwrap_err();
        // the truncated byte sits at offset 3 of the file (2 header + 1 tag)
        assert!(matches!(e, ImportError::Wire { offset: 3, .. }), "{e:?}");
    }

    #[test]
    fn packed_and_unpacked_int64s() {
        let mut w = Writer::new();
        w.packed_int64s(1, &[1, 300, 7]);
        w.int(1, 9); // unpacked form of the same field
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        let mut vs = Vec::new();
        while !r.at_end() {
            let (f, wt) = r.tag().unwrap();
            assert_eq!(f, 1);
            r.int64s(wt, &mut vs).unwrap();
        }
        assert_eq!(vs, vec![1, 300, 7, 9]);
    }

    #[test]
    fn floats_round_trip() {
        let mut w = Writer::new();
        w.float(2, 0.125);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        let (_, wt) = r.tag().unwrap();
        let mut vs = Vec::new();
        r.floats(wt, &mut vs).unwrap();
        assert_eq!(vs, vec![0.125]);
    }
}
