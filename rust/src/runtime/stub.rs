//! Stub runtime used when the `pjrt` feature is off: constructing the
//! client reports a typed [`CompileError::Unsupported`], so callers can
//! probe availability with `Runtime::cpu().is_ok()` and skip.

use crate::compiler::CompileError;
use crate::funcsim::Tensor;
use crate::Result;
use std::path::Path;

const MSG: &str = "PJRT runtime not available: rebuild with `--features pjrt` \
                   and a vendored `xla` crate (see MIGRATION.md)";

/// PJRT CPU runtime (stub: the `pjrt` feature is disabled).
pub struct Runtime {
    _private: (),
}

impl Runtime {
    /// Always fails in this build; the real client needs the `pjrt`
    /// feature.
    pub fn cpu() -> Result<Runtime> {
        Err(CompileError::unsupported(MSG))
    }

    /// Always `"stub"` in this build.
    pub fn platform(&self) -> String {
        "stub".to_string()
    }

    /// Unreachable in practice (`cpu()` never yields a stub instance),
    /// but kept so the API matches the real backend.
    pub fn load(&mut self, _path: &Path) -> Result<usize> {
        Err(CompileError::unsupported(MSG))
    }

    /// Unreachable in practice; see [`Runtime::load`].
    pub fn run_i8(&self, _id: usize, _inputs: &[&Tensor]) -> Result<Vec<i8>> {
        Err(CompileError::unsupported(MSG))
    }

    /// Unreachable in practice; see [`Runtime::load`].
    pub fn run_i8_to_i32(&self, _id: usize, _inputs: &[&Tensor]) -> Result<Vec<i32>> {
        Err(CompileError::unsupported(MSG))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unsupported() {
        match Runtime::cpu() {
            Err(CompileError::Unsupported(m)) => assert!(m.contains("pjrt")),
            _ => panic!("stub must fail with Unsupported"),
        }
    }
}
