//! PJRT runtime: load and execute AOT-compiled artifacts.
//!
//! **Deprecation path:** direct use of [`Runtime`] as an execution entry
//! point is superseded by the unified [`crate::engine::ExecutionBackend`]
//! API ([`crate::engine::PjrtBackend`] is the PJRT implementation); this
//! module remains the low-level HLO-artifact loader the backend builds
//! on. New code should run packed [`crate::program::Program`] artifacts
//! through [`crate::engine`] — see MIGRATION.md §"The run side".
//!
//! The real backend wraps the `xla` crate (PJRT C API):
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` → `compile` →
//! `execute`. That crate is not in the offline registry, so the backend
//! is gated behind the **`pjrt`** cargo feature (see MIGRATION.md for how
//! to vendor it). Without the feature, [`Runtime::cpu`] returns
//! [`crate::CompileError::Unsupported`] and every PJRT-dependent test and
//! example skips gracefully — the artifact loaders below stay available
//! either way.

mod artifacts;

pub use artifacts::{artifacts_dir, load_expected_logits, load_input_tensor};

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::{LoadedModel, Runtime};

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::Runtime;

use crate::funcsim::Tensor;
use crate::graph::Shape;

/// Build a rank-2 tensor helper for the matmul artifact.
pub fn matrix(h: usize, w: usize, data: Vec<i8>) -> Tensor {
    Tensor::from_vec(Shape::new(h, w, 1), data)
}
