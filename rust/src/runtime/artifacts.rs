//! Artifact-directory helpers (input / expected-output JSON loaders).

use crate::funcsim::Tensor;
use crate::graph::Shape;
use crate::serialize::{parse, Json};
use crate::compiler::CompileError;
use crate::Result;
use std::path::{Path, PathBuf};

/// Locate `artifacts/`: `$SHORTCUTFUSION_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("SHORTCUTFUSION_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// Load `tinynet_input.json`: `{"shape":[h,w,c],"data":[...]}`.
pub fn load_input_tensor(path: &Path) -> Result<Tensor> {
    let doc = read_json(path)?;
    let shape = doc
        .get("shape")
        .and_then(Json::as_arr)
        .ok_or_else(|| CompileError::parse("missing shape"))?;
    if shape.len() != 3 {
        return Err(CompileError::parse("input shape must be [h,w,c]"));
    }
    let dim = |i: usize| shape[i].as_usize().ok_or_else(|| CompileError::parse("bad dim"));
    let s = Shape::new(dim(0)?, dim(1)?, dim(2)?);
    let data = i8_array(&doc, "data")?;
    if data.len() != s.numel() {
        return Err(CompileError::parse(format!("data length {} != {}", data.len(), s.numel())));
    }
    Ok(Tensor::from_vec(s, data))
}

/// Load `tinynet_expected.json`: `{"logits":[...]}`.
pub fn load_expected_logits(path: &Path) -> Result<Vec<i8>> {
    let doc = read_json(path)?;
    i8_array(&doc, "logits")
}

fn read_json(path: &Path) -> Result<Json> {
    let text = std::fs::read_to_string(path).map_err(|e| CompileError::io(path, e))?;
    parse(&text).map_err(|e| CompileError::parse(format!("{}: {e}", path.display())))
}

fn i8_array(doc: &Json, key: &str) -> Result<Vec<i8>> {
    doc.get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| CompileError::parse(format!("missing {key}")))?
        .iter()
        .map(|v| {
            v.as_f64()
                .filter(|f| f.fract() == 0.0 && (-128.0..=127.0).contains(f))
                .map(|f| f as i8)
                .ok_or_else(|| CompileError::parse(format!("bad i8 in {key}")))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_input_json() {
        let dir = std::env::temp_dir().join("sf_artifacts_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("in.json");
        std::fs::write(&p, r#"{"shape":[1,2,2],"data":[1,-2,3,-4]}"#).unwrap();
        let t = load_input_tensor(&p).unwrap();
        assert_eq!(t.shape, Shape::new(1, 2, 2));
        assert_eq!(t.data, vec![1, -2, 3, -4]);
    }

    #[test]
    fn rejects_wrong_length() {
        let dir = std::env::temp_dir().join("sf_artifacts_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.json");
        std::fs::write(&p, r#"{"shape":[1,2,2],"data":[1]}"#).unwrap();
        assert!(load_input_tensor(&p).is_err());
    }
}
