//! Real PJRT backend (the `pjrt` feature): wraps the `xla` crate
//! (PJRT C API): `PjRtClient::cpu()` → `HloModuleProto::from_text_file`
//! → `compile` → `execute`. Python never runs here — the artifacts under
//! `artifacts/` were produced once by `make artifacts` and the rust
//! binary is self-contained afterwards.
//!
//! **Build prerequisite:** the `xla` crate is not in the offline
//! registry. If `--features pjrt` fails right below with
//! `unresolved import xla` (E0433), vendor the crate first and add
//! `xla = { path = "third_party/xla-rs" }` to `[dependencies]` in
//! Cargo.toml — see MIGRATION.md. The feature deliberately ships
//! without the dependency so the default build stays offline-clean.

use crate::compiler::CompileError;
use crate::funcsim::Tensor;
use crate::Result;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A compiled executable with its source path.
pub struct LoadedModel {
    /// Source HLO artifact path.
    pub path: PathBuf,
    exe: xla::PjRtLoadedExecutable,
}

/// PJRT CPU runtime with a compile cache keyed by artifact path.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: HashMap<PathBuf, usize>,
    models: Vec<LoadedModel>,
}

impl Runtime {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| rt_err(format!("PJRT cpu client: {e:?}")))?;
        Ok(Runtime { client, cache: HashMap::new(), models: Vec::new() })
    }

    /// PJRT platform name (e.g. `"cpu"`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it (cached).
    pub fn load(&mut self, path: &Path) -> Result<usize> {
        if let Some(&id) = self.cache.get(path) {
            return Ok(id);
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| rt_err("non-utf8 path".into()))?,
        )
        .map_err(|e| rt_err(format!("parsing {}: {e:?}", path.display())))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| rt_err(format!("compiling {}: {e:?}", path.display())))?;
        let id = self.models.len();
        self.models.push(LoadedModel { path: path.to_path_buf(), exe });
        self.cache.insert(path.to_path_buf(), id);
        Ok(id)
    }

    /// Execute a loaded model on int8 HWC tensors; the exported jax
    /// functions return 1-tuples (`return_tuple=True` lowering).
    pub fn run_i8(&self, id: usize, inputs: &[&Tensor]) -> Result<Vec<i8>> {
        let out = self.run_raw(id, inputs)?;
        out.to_vec::<i8>().map_err(|e| rt_err(format!("to_vec<i8>: {e:?}")))
    }

    /// Execute with int8 inputs returning int32 outputs (matmul kernel).
    pub fn run_i8_to_i32(&self, id: usize, inputs: &[&Tensor]) -> Result<Vec<i32>> {
        let out = self.run_raw(id, inputs)?;
        out.to_vec::<i32>().map_err(|e| rt_err(format!("to_vec<i32>: {e:?}")))
    }

    fn run_raw(&self, id: usize, inputs: &[&Tensor]) -> Result<xla::Literal> {
        let model = self.models.get(id).ok_or_else(|| rt_err(format!("bad model id {id}")))?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| {
                // i8 is not a `NativeType` in the crate; build the S8
                // literal from raw bytes instead.
                let dims: Vec<usize> = tensor_dims(t).into_iter().map(|d| d as usize).collect();
                let bytes: &[u8] = unsafe {
                    std::slice::from_raw_parts(t.data.as_ptr() as *const u8, t.data.len())
                };
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::S8,
                    &dims,
                    bytes,
                )
                .map_err(|e| rt_err(format!("S8 literal: {e:?}")))
            })
            .collect::<Result<_>>()?;
        let result = model
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| rt_err(format!("executing {}: {e:?}", model.path.display())))?[0][0]
            .to_literal_sync()
            .map_err(|e| rt_err(format!("fetch: {e:?}")))?;
        result.to_tuple1().map_err(|e| rt_err(format!("untuple: {e:?}")))
    }
}

/// HWC tensor dims for the literal: vectors export as rank-1 `[C]`
/// (matching `Shape::vec` lowering), 2-D matrices as `[H, W]` when C = 1
/// used by the matmul artifact, full fmaps as `[H, W, C]`.
fn tensor_dims(t: &Tensor) -> Vec<i64> {
    let s = t.shape;
    if s.h == 1 && s.w == 1 {
        vec![s.c as i64]
    } else if s.c == 1 {
        vec![s.h as i64, s.w as i64]
    } else {
        vec![s.h as i64, s.w as i64, s.c as i64]
    }
}

/// Wrap an `xla` backend failure in the typed error. `Exec`, not
/// `Unsupported`: a real backend that fails must not be mistaken for the
/// feature-off stub (callers skip on `Unsupported` only).
fn rt_err(msg: String) -> CompileError {
    CompileError::Exec(format!("pjrt: {msg}"))
}
