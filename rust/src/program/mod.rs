//! The deployable program artifact (§III-A).
//!
//! The paper's inference driver "packs parameters, input, and *all*
//! instructions and ships them to the accelerator at once". [`Program`] is
//! that payload as a first-class, savable artifact: the encoded 11-word
//! instruction stream, the per-group memory assignment flags that ride in
//! the packed header (buffer placements, staging / long-path DMA bits),
//! the full target [`AccelConfig`], the frozen model graph, and — when the
//! compile attached them — the quantized parameters. A program is
//! *self-contained*: loading one requires no zoo builder, no preset and no
//! re-run of the optimizer, which is what lets the [`crate::engine`]
//! backends execute it as-is.
//!
//! Producing one is the sixth pipeline stage:
//!
//! ```no_run
//! use shortcutfusion::compiler::Compiler;
//! use shortcutfusion::config::AccelConfig;
//! use shortcutfusion::program::Program;
//! use shortcutfusion::zoo;
//!
//! let compiler = Compiler::new(AccelConfig::kcu1500_int8());
//! let analyzed = compiler.analyze(&zoo::resnet18(224)).unwrap();
//! let lowered = compiler
//!     .lower(&compiler.allocate(&compiler.optimize(&analyzed).unwrap()).unwrap())
//!     .unwrap();
//! let program = compiler.pack(&lowered).unwrap();
//! program.save(std::path::Path::new("resnet18.sfp")).unwrap();
//! let again = Program::load(std::path::Path::new("resnet18.sfp")).unwrap();
//! assert_eq!(again.stream().words, program.stream().words);
//! ```
//!
//! On disk a program is a versioned, checksummed binary container
//! ([`format`]); save → load → save is byte-identical.

pub mod format;

use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;

use crate::alloc::{AllocResult, BufAssign, Loc};
use crate::analyzer::{analyze, GroupedGraph};
use crate::compiler::CompileError;
use crate::config::AccelConfig;
use crate::funcsim::{GroupParams, Params};
use crate::graph::{validate, Shape};
use crate::isa::{decode, InstructionStream, ReuseMode, WORDS_PER_INSTR};
use crate::serialize::{graph_from_json, graph_to_json, parse, Json};
use crate::Result;

use format::{SectionReader, SectionWriter};

/// Identifies the meta section of the container.
const PROGRAM_FORMAT: &str = "shortcutfusion-program";

/// A named feature-map tensor at a shard boundary: the producing node's
/// name in the *unsharded* model and its `H×W×C` shape. Pairs of these
/// descriptors (egress of shard *i*, ingress of shard *i+1*) are what the
/// [`crate::engine::ShardedBackend`] validates before handing a tensor
/// across devices.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorDesc {
    /// Name of the node producing the tensor in the unsharded graph.
    pub name: String,
    /// Feature-map shape of the tensor.
    pub shape: Shape,
}

impl TensorDesc {
    /// Transfer size in bytes at `qa` bytes per element.
    pub fn bytes(&self, qa: usize) -> usize {
        self.shape.bytes(qa)
    }

    /// The descriptor's JSON record — shared by the packed artifact's
    /// shard metadata and `ShardPlan::to_json`.
    pub(crate) fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("h", Json::num(self.shape.h as f64)),
            ("w", Json::num(self.shape.w as f64)),
            ("c", Json::num(self.shape.c as f64)),
        ])
    }

    fn from_json(doc: &Json) -> Result<TensorDesc> {
        let dim = |key: &str| -> Result<usize> {
            doc.get(key).and_then(Json::as_usize).ok_or_else(|| {
                CompileError::artifact(format!("tensor descriptor: missing {key:?}"))
            })
        };
        Ok(TensorDesc {
            name: doc
                .get("name")
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| CompileError::artifact("tensor descriptor: missing name"))?,
            shape: Shape::new(dim("h")?, dim("w")?, dim("c")?),
        })
    }
}

/// Position of a program within a multi-device pipeline
/// ([`crate::shard::ShardPlan`]): which shard it is, how many exist, and
/// the ingress/egress tensor descriptors its neighbours must match.
/// Attached by [`Program::with_boundary`]; absent on unsharded programs
/// (a 1-device plan packs exactly the classic artifact).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardBoundary {
    /// Pipeline position, `0..count`.
    pub index: usize,
    /// Total shard count of the plan (at least 2).
    pub count: usize,
    /// Tensor this shard receives (`None` exactly for the first shard).
    pub ingress: Option<TensorDesc>,
    /// Tensor this shard emits (`None` exactly for the final shard).
    pub egress: Option<TensorDesc>,
}

impl ShardBoundary {
    fn to_json(&self) -> Json {
        let opt = |t: &Option<TensorDesc>| match t {
            None => Json::Null,
            Some(t) => t.to_json(),
        };
        Json::obj(vec![
            ("index", Json::num(self.index as f64)),
            ("count", Json::num(self.count as f64)),
            ("ingress", opt(&self.ingress)),
            ("egress", opt(&self.egress)),
        ])
    }

    fn from_json(doc: &Json) -> Result<ShardBoundary> {
        let uint = |key: &str| -> Result<usize> {
            doc.get(key)
                .and_then(Json::as_usize)
                .ok_or_else(|| CompileError::artifact(format!("shard record: missing {key:?}")))
        };
        let tensor = |key: &str| -> Result<Option<TensorDesc>> {
            match doc.get(key) {
                None | Some(Json::Null) => Ok(None),
                Some(v) => TensorDesc::from_json(v).map(Some),
            }
        };
        Ok(ShardBoundary {
            index: uint("index")?,
            count: uint("count")?,
            ingress: tensor("ingress")?,
            egress: tensor("egress")?,
        })
    }
}

/// A packed, deployable program: everything the accelerator-side driver
/// needs to run one network, plus the derived views the simulation
/// backends execute against.
///
/// The serialized state is `(model, strategy, config, graph, assigns,
/// words, params, shard boundary)`; the grouped graph and decoded
/// instruction stream are rebuilt deterministically at load/pack time and
/// never stored.
#[derive(Debug, Clone)]
pub struct Program {
    model: String,
    strategy: String,
    cfg: AccelConfig,
    /// Per-group buffer placements + header flags (staging DMA,
    /// long-path DRAM copy) — the allocator decisions that are not
    /// encoded inside the 11 instruction words.
    assigns: Vec<BufAssign>,
    params: Option<Params>,
    /// Pipeline position + hand-off descriptors when this program is one
    /// shard of a multi-device plan (`None` for unsharded programs).
    boundary: Option<ShardBoundary>,
    /// Decoded view of the packed words (validated at construction).
    stream: InstructionStream,
    grouped: Arc<GroupedGraph>,
}

impl Program {
    /// Assemble a program from compile products that share one grouped
    /// graph (what [`crate::compiler::Compiler::pack`] and
    /// [`crate::compiler::Lowered::into_program`] call). Validates that
    /// the words decode and that instruction / assignment counts match
    /// the graph's groups.
    pub fn from_parts(
        model: String,
        strategy: String,
        cfg: AccelConfig,
        grouped: Arc<GroupedGraph>,
        assigns: Vec<BufAssign>,
        words: Vec<u32>,
        params: Option<Params>,
    ) -> Result<Program> {
        if model != grouped.graph.name {
            return Err(CompileError::artifact(format!(
                "model name {:?} does not match the embedded graph {:?}",
                model, grouped.graph.name
            )));
        }
        if words.len() % WORDS_PER_INSTR != 0 {
            return Err(CompileError::artifact(format!(
                "{} stream words is not a multiple of {WORDS_PER_INSTR}",
                words.len()
            )));
        }
        let n = words.len() / WORDS_PER_INSTR;
        if n != grouped.groups.len() {
            return Err(CompileError::artifact(format!(
                "{n} instructions for {} groups",
                grouped.groups.len()
            )));
        }
        if assigns.len() != grouped.groups.len() {
            return Err(CompileError::artifact(format!(
                "{} memory assignments for {} groups",
                assigns.len(),
                grouped.groups.len()
            )));
        }
        let mut instrs = Vec::with_capacity(n);
        for i in 0..n {
            let chunk: [u32; WORDS_PER_INSTR] =
                words[i * WORDS_PER_INSTR..(i + 1) * WORDS_PER_INSTR].try_into().unwrap();
            let ins = decode(&chunk)
                .map_err(|e| CompileError::artifact(format!("instruction {i}: {e}")))?;
            instrs.push(ins);
        }
        // A self-contained artifact must be self-consistent: the packed
        // parameters must imply exactly the quant shifts the instruction
        // words encode (they do when the stream was lowered by the same
        // params-carrying compiler; they don't if params were bolted on
        // after an unparameterized lower).
        if let Some(p) = params.as_ref() {
            for (gi, ins) in instrs.iter().enumerate() {
                let expect = crate::compiler::quant_shift_for(&grouped, gi, Some(p))?;
                if expect != ins.quant_shift {
                    return Err(CompileError::artifact(format!(
                        "group {gi}: instruction encodes quant_shift {} but the packed \
                         parameters imply {expect} — re-lower with the params-carrying \
                         compiler before packing",
                        ins.quant_shift
                    )));
                }
            }
        }
        Ok(Program {
            model,
            strategy,
            cfg,
            assigns,
            params,
            boundary: None,
            stream: InstructionStream { instrs, words },
            grouped,
        })
    }

    /// Stamp this program as one shard of a multi-device pipeline.
    /// Validates the descriptors against the embedded graph: the ingress
    /// tensor must match the graph's input feed, the egress tensor must
    /// name a node of the graph with a matching shape, and exactly the
    /// first/last shards omit ingress/egress.
    pub fn with_boundary(mut self, boundary: ShardBoundary) -> Result<Program> {
        if boundary.count < 2 {
            return Err(CompileError::artifact(format!(
                "shard record: count {} — a pipeline has at least 2 shards",
                boundary.count
            )));
        }
        if boundary.index >= boundary.count {
            return Err(CompileError::artifact(format!(
                "shard record: index {} out of range for {} shards",
                boundary.index, boundary.count
            )));
        }
        if (boundary.index == 0) != boundary.ingress.is_none() {
            return Err(CompileError::artifact(
                "shard record: exactly the first shard reads the model input \
                 (no ingress descriptor)",
            ));
        }
        if (boundary.index + 1 == boundary.count) != boundary.egress.is_none() {
            return Err(CompileError::artifact(
                "shard record: exactly the final shard produces the model output \
                 (no egress descriptor)",
            ));
        }
        if let Some(ingress) = &boundary.ingress {
            if ingress.shape != self.input_shape() {
                return Err(CompileError::artifact(format!(
                    "shard record: ingress {} is {} but the graph input feed is {}",
                    ingress.name,
                    ingress.shape,
                    self.input_shape()
                )));
            }
        }
        if let Some(egress) = &boundary.egress {
            match self.grouped.graph.find(&egress.name) {
                None => {
                    return Err(CompileError::artifact(format!(
                        "shard record: egress {:?} is not a node of the shard graph",
                        egress.name
                    )))
                }
                Some(id) if self.grouped.graph.node(id).out_shape != egress.shape => {
                    return Err(CompileError::artifact(format!(
                        "shard record: egress {} is {} but node produces {}",
                        egress.name,
                        egress.shape,
                        self.grouped.graph.node(id).out_shape
                    )))
                }
                Some(_) => {}
            }
        }
        self.boundary = Some(boundary);
        Ok(self)
    }

    // ---- inspection -----------------------------------------------------

    /// Model name recorded at pack time.
    pub fn model(&self) -> &str {
        &self.model
    }

    /// Name of the [`crate::compiler::ReuseStrategy`] that chose the
    /// packed policy.
    pub fn strategy(&self) -> &str {
        &self.strategy
    }

    /// The embedded target configuration.
    pub fn cfg(&self) -> &AccelConfig {
        &self.cfg
    }

    /// The fused model this program executes (rebuilt from the embedded
    /// frozen graph on load).
    pub fn grouped(&self) -> &Arc<GroupedGraph> {
        &self.grouped
    }

    /// The packed 11-word instruction stream (decoded + raw words).
    pub fn stream(&self) -> &InstructionStream {
        &self.stream
    }

    /// Per-group placements and packed-header flags.
    pub fn assigns(&self) -> &[BufAssign] {
        &self.assigns
    }

    /// Quantized parameters, when the compile attached them.
    pub fn params(&self) -> Option<&Params> {
        self.params.as_ref()
    }

    /// Pipeline position + hand-off descriptors, when this program is
    /// one shard of a multi-device plan.
    pub fn boundary(&self) -> Option<&ShardBoundary> {
        self.boundary.as_ref()
    }

    /// Expected input tensor shape.
    pub fn input_shape(&self) -> Shape {
        self.grouped.graph.input().out_shape
    }

    /// Cheap 64-bit identity for segment-level caching
    /// ([`crate::pool::SegmentId`]): FNV-1a over the pack-time metadata
    /// (model, strategy, target name, precisions, params presence, shard
    /// position) and the packed instruction words. Deliberately does
    /// *not* hash the weight payload — the stream already pins the exact
    /// lowering, and hashing megabytes of weights per request would
    /// dominate a pool hit.
    pub fn fingerprint(&self) -> u64 {
        let mut h = fnv1a64(FNV64_OFFSET, self.model.as_bytes());
        h = fnv1a64(h, &[0]);
        h = fnv1a64(h, self.strategy.as_bytes());
        h = fnv1a64(h, &[0]);
        h = fnv1a64(h, self.cfg.name.as_bytes());
        h = fnv1a64(h, &[0]);
        h = fnv1a64(h, &[self.cfg.qa as u8, self.cfg.qw as u8, self.params.is_some() as u8]);
        match &self.boundary {
            None => h = fnv1a64(h, &[0]),
            Some(b) => {
                h = fnv1a64(h, &[1]);
                h = fnv1a64(h, &(b.index as u64).to_le_bytes());
                h = fnv1a64(h, &(b.count as u64).to_le_bytes());
            }
        }
        for w in &self.stream.words {
            h = fnv1a64(h, &w.to_le_bytes());
        }
        h
    }

    /// Device-DRAM bytes this program's paged weight segment occupies:
    /// the parameter payload (exact packed sizes when params are present,
    /// otherwise the analytical weight footprint at the target's `Q_W`)
    /// plus the instruction stream shipped alongside it.
    pub fn resident_bytes(&self) -> u64 {
        let payload = match &self.params {
            Some(p) => p
                .groups
                .values()
                .map(|g| (g.weights.len() + 4 * g.bias.len()) as u64
                    + g.lut.as_ref().map_or(0, |l| l.len() as u64))
                .sum(),
            None => self.grouped.graph.total_weight_bytes(self.cfg.qw as u64),
        };
        payload + self.stream.byte_size() as u64
    }

    /// The per-group reuse policy, read back from the *packed*
    /// instructions (the artifact's source of truth, not a copy of the
    /// optimizer output).
    pub fn policy(&self) -> Vec<ReuseMode> {
        self.stream.instrs.iter().map(|i| i.reuse).collect()
    }

    /// Placement view for the timing model. Only the per-group
    /// assignments are part of the artifact; the occupancy statistics an
    /// allocator run would also report are not meaningful for a loaded
    /// program and are zeroed.
    pub fn alloc_view(&self) -> AllocResult {
        AllocResult {
            assigns: self.assigns.clone(),
            buf_peak: [0; 3],
            aux_peak: 0,
            spill_bytes: 0,
            spill_write_bytes: 0,
            spill_events: 0,
        }
    }

    // ---- serialization --------------------------------------------------

    /// Serialize to the versioned, checksummed container format.
    /// Deterministic: equal programs produce equal bytes.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut w = SectionWriter::new();
        w.section(self.meta_json().to_string_compact().as_bytes());
        w.section(graph_to_json(&self.grouped.graph).to_string_compact().as_bytes());
        let mut words_bytes = Vec::with_capacity(self.stream.words.len() * 4);
        for word in &self.stream.words {
            words_bytes.extend_from_slice(&word.to_le_bytes());
        }
        w.section(&words_bytes);
        match &self.params {
            Some(p) => {
                let mut pb = vec![1u8];
                pb.extend_from_slice(&params_to_bytes(p));
                w.section(&pb);
            }
            None => w.section(&[0u8]),
        }
        format::wrap(&w.finish())
    }

    /// Parse a container produced by [`Program::to_bytes`], verifying
    /// the checksum and rebuilding the derived views.
    pub fn from_bytes(bytes: &[u8]) -> Result<Program> {
        let payload = format::unwrap(bytes)?;
        let mut r = SectionReader::new(payload);

        let meta_text = std::str::from_utf8(r.section()?)
            .map_err(|_| CompileError::artifact("meta section is not UTF-8"))?;
        let meta = parse(meta_text)
            .map_err(|e| CompileError::artifact(format!("meta section: {e}")))?;
        if meta.get("format").and_then(Json::as_str) != Some(PROGRAM_FORMAT) {
            return Err(CompileError::artifact("meta section is not a program record"));
        }
        let text_field = |key: &str| -> Result<String> {
            meta.get(key)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| CompileError::artifact(format!("meta: missing {key:?}")))
        };
        let model = text_field("model")?;
        let strategy = text_field("strategy")?;
        let cfg = AccelConfig::from_json(
            meta.get("config")
                .ok_or_else(|| CompileError::artifact("meta: missing config"))?,
        )?;
        let assigns = assigns_from_json(
            meta.get("assigns")
                .and_then(Json::as_arr)
                .ok_or_else(|| CompileError::artifact("meta: missing assigns"))?,
        )?;

        let graph_text = std::str::from_utf8(r.section()?)
            .map_err(|_| CompileError::artifact("graph section is not UTF-8"))?;
        let graph_doc = parse(graph_text)
            .map_err(|e| CompileError::artifact(format!("graph section: {e}")))?;
        let graph = graph_from_json(&graph_doc)?;

        let words_bytes = r.section()?;
        if words_bytes.len() % 4 != 0 {
            return Err(CompileError::artifact("instruction section is not word-aligned"));
        }
        let words: Vec<u32> = words_bytes
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect();

        let params_section = r.section()?;
        let params = match params_section.first() {
            Some(0) if params_section.len() == 1 => None,
            Some(1) => Some(params_from_bytes(&params_section[1..])?),
            _ => return Err(CompileError::artifact("malformed params section")),
        };
        if !r.done() {
            return Err(CompileError::artifact("trailing bytes after the last section"));
        }

        validate(&graph)?;
        let grouped = Arc::new(analyze(&graph));
        let program =
            Program::from_parts(model, strategy, cfg, grouped, assigns, words, params)?;
        match meta.get("shard") {
            None | Some(Json::Null) => Ok(program),
            Some(doc) => program.with_boundary(ShardBoundary::from_json(doc)?),
        }
    }

    /// Write the binary container to disk.
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_bytes()).map_err(|e| CompileError::io(path, e))
    }

    /// Read a binary container from disk.
    pub fn load(path: &Path) -> Result<Program> {
        let bytes = std::fs::read(path).map_err(|e| CompileError::io(path, e))?;
        Program::from_bytes(&bytes)
    }

    /// Compact inspection record (mirrors the stage artifacts'
    /// `summary_json`): O(metadata) — it does not re-serialize the
    /// artifact.
    pub fn summary_json(&self) -> Json {
        Json::obj(vec![
            ("stage", Json::str("program")),
            ("model", Json::str(&self.model)),
            ("strategy", Json::str(&self.strategy)),
            ("target", Json::str(&self.cfg.name)),
            ("instructions", Json::num(self.stream.len() as f64)),
            ("stream_bytes", Json::num(self.stream.byte_size() as f64)),
            ("has_params", Json::Bool(self.params.is_some())),
            (
                "shard",
                match &self.boundary {
                    None => Json::Null,
                    Some(b) => Json::str(&format!("{}/{}", b.index + 1, b.count)),
                },
            ),
        ])
    }

    fn meta_json(&self) -> Json {
        let assigns: Vec<Json> = self
            .assigns
            .iter()
            .map(|a| {
                Json::obj(vec![
                    ("in", Json::Str(loc_code(&a.in_loc))),
                    ("out", Json::Str(loc_code(&a.out_loc))),
                    (
                        "aux",
                        a.aux_loc.map(|l| Json::Str(loc_code(&l))).unwrap_or(Json::Null),
                    ),
                    ("staged", Json::Bool(a.staged_input)),
                    ("also_dram", Json::Bool(a.also_dram)),
                ])
            })
            .collect();
        let mut pairs = vec![
            ("format", Json::str(PROGRAM_FORMAT)),
            ("version", Json::num(format::FORMAT_VERSION as f64)),
            ("model", Json::str(&self.model)),
            ("strategy", Json::str(&self.strategy)),
            ("config", self.cfg.to_json()),
            ("assigns", Json::Arr(assigns)),
        ];
        if let Some(b) = &self.boundary {
            // only sharded programs carry the key, so every pre-shard
            // artifact (and every 1-device plan) keeps its exact bytes
            pairs.push(("shard", b.to_json()));
        }
        Json::obj(pairs)
    }
}

impl crate::compiler::Lowered {
    /// Consume the lowered stage into a deployable [`Program`]. Pass the
    /// quantized parameters to pack them into the artifact (what
    /// [`crate::compiler::Compiler::pack`] does automatically when the
    /// compiler carries params).
    pub fn into_program(self, params: Option<Params>) -> Result<Program> {
        Program::from_parts(
            self.model,
            self.strategy.to_string(),
            self.cfg,
            self.grouped,
            self.alloc.assigns,
            self.stream.words,
            params,
        )
    }
}

/// FNV-1a 64-bit offset basis (the 64-bit sibling of
/// [`format::fnv1a32`]'s constants).
const FNV64_OFFSET: u64 = 0xCBF2_9CE4_8422_2325;

/// Fold `bytes` into a running FNV-1a 64-bit hash.
fn fnv1a64(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

fn loc_code(l: &Loc) -> String {
    match l {
        Loc::Buf(b) => format!("b{b}"),
        Loc::Dram => "dram".to_string(),
        Loc::Aux => "aux".to_string(),
    }
}

fn loc_from_code(s: &str) -> Result<Loc> {
    match s {
        "dram" => Ok(Loc::Dram),
        "aux" => Ok(Loc::Aux),
        _ => s
            .strip_prefix('b')
            .and_then(|d| d.parse::<u8>().ok())
            .map(Loc::Buf)
            .ok_or_else(|| CompileError::artifact(format!("bad location code {s:?}"))),
    }
}

fn assigns_from_json(arr: &[Json]) -> Result<Vec<BufAssign>> {
    arr.iter()
        .map(|a| {
            let loc = |key: &str| -> Result<Loc> {
                a.get(key)
                    .and_then(Json::as_str)
                    .ok_or_else(|| CompileError::artifact(format!("assign: missing {key:?}")))
                    .and_then(loc_from_code)
            };
            let flag = |key: &str| -> Result<bool> {
                a.get(key)
                    .and_then(Json::as_bool)
                    .ok_or_else(|| CompileError::artifact(format!("assign: missing {key:?}")))
            };
            let aux_loc = match a.get("aux") {
                None | Some(Json::Null) => None,
                Some(v) => Some(
                    v.as_str()
                        .ok_or_else(|| CompileError::artifact("assign: bad aux"))
                        .and_then(loc_from_code)?,
                ),
            };
            Ok(BufAssign {
                in_loc: loc("in")?,
                out_loc: loc("out")?,
                aux_loc,
                also_dram: flag("also_dram")?,
                staged_input: flag("staged")?,
            })
        })
        .collect()
}

/// Deterministic binary encoding of the quantized parameter store
/// (groups in sorted-name order; weights/LUTs as raw int8, biases as
/// little-endian int32).
fn params_to_bytes(p: &Params) -> Vec<u8> {
    let mut names: Vec<&String> = p.groups.keys().collect();
    names.sort();
    let mut w = SectionWriter::new();
    w.raw(&(names.len() as u64).to_le_bytes());
    for name in names {
        let gp = &p.groups[name];
        w.section(name.as_bytes());
        let weights: Vec<u8> = gp.weights.iter().map(|&v| v as u8).collect();
        w.section(&weights);
        let mut bias = Vec::with_capacity(gp.bias.len() * 4);
        for &b in &gp.bias {
            bias.extend_from_slice(&b.to_le_bytes());
        }
        w.section(&bias);
        w.raw(&gp.shift.to_le_bytes());
        w.raw(&gp.elt_shift.to_le_bytes());
        match &gp.lut {
            None => w.raw(&[0]),
            Some(lut) => {
                w.raw(&[1]);
                let bytes: Vec<u8> = lut.iter().map(|&v| v as u8).collect();
                w.section(&bytes);
            }
        }
    }
    w.finish()
}

fn params_from_bytes(bytes: &[u8]) -> Result<Params> {
    let mut r = SectionReader::new(bytes);
    let count = u64::from_le_bytes(r.raw(8)?.try_into().unwrap());
    let mut groups = HashMap::new();
    for _ in 0..count {
        let name = String::from_utf8(r.section()?.to_vec())
            .map_err(|_| CompileError::artifact("params: group name is not UTF-8"))?;
        let weights: Vec<i8> = r.section()?.iter().map(|&b| b as i8).collect();
        let bias_bytes = r.section()?;
        if bias_bytes.len() % 4 != 0 {
            return Err(CompileError::artifact(format!("params {name}: bias not i32-aligned")));
        }
        let bias: Vec<i32> = bias_bytes
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        let shift = i32::from_le_bytes(r.raw(4)?.try_into().unwrap());
        let elt_shift = i32::from_le_bytes(r.raw(4)?.try_into().unwrap());
        let lut = match r.raw(1)?[0] {
            0 => None,
            1 => Some(r.section()?.iter().map(|&b| b as i8).collect::<Vec<i8>>()),
            other => {
                return Err(CompileError::artifact(format!(
                    "params {name}: bad LUT flag {other}"
                )))
            }
        };
        groups.insert(name, GroupParams { weights, bias, shift, elt_shift, lut });
    }
    if !r.done() {
        return Err(CompileError::artifact("params: trailing bytes"));
    }
    Ok(Params { groups })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::Compiler;
    use crate::zoo;

    fn tinynet_program(params: bool) -> Program {
        crate::testutil::pack_program(&zoo::tinynet(), params.then_some(9))
    }

    #[test]
    fn pack_save_load_round_trip() {
        let program = tinynet_program(false);
        let bytes = program.to_bytes();
        let loaded = Program::from_bytes(&bytes).unwrap();
        assert_eq!(loaded.model(), program.model());
        assert_eq!(loaded.strategy(), program.strategy());
        assert_eq!(loaded.cfg(), program.cfg());
        assert_eq!(loaded.stream().words, program.stream().words);
        assert_eq!(loaded.policy(), program.policy());
        assert_eq!(loaded.input_shape(), program.input_shape());
        assert_eq!(loaded.to_bytes(), bytes, "re-save must be byte-identical");
    }

    #[test]
    fn params_survive_packing() {
        let program = tinynet_program(true);
        let loaded = Program::from_bytes(&program.to_bytes()).unwrap();
        let (a, b) = (program.params().unwrap(), loaded.params().unwrap());
        assert_eq!(a.groups.len(), b.groups.len());
        for (name, gp) in &a.groups {
            let lp = b.get(name).unwrap_or_else(|| panic!("missing group {name}"));
            assert_eq!(gp.weights, lp.weights, "{name}");
            assert_eq!(gp.bias, lp.bias, "{name}");
            assert_eq!(gp.shift, lp.shift, "{name}");
            assert_eq!(gp.elt_shift, lp.elt_shift, "{name}");
            assert_eq!(gp.lut, lp.lut, "{name}");
        }
        assert_eq!(loaded.to_bytes(), program.to_bytes());
    }

    #[test]
    fn fingerprint_is_stable_across_round_trips_and_distinguishes_programs() {
        let plain = tinynet_program(false);
        let with_params = tinynet_program(true);
        let loaded = Program::from_bytes(&plain.to_bytes()).unwrap();
        assert_eq!(plain.fingerprint(), loaded.fingerprint(), "load changed the identity");
        assert_ne!(
            plain.fingerprint(),
            with_params.fingerprint(),
            "params presence must change the segment identity"
        );
        let other = crate::testutil::pack_program(&zoo::resnet18(64), None);
        assert_ne!(plain.fingerprint(), other.fingerprint());
    }

    #[test]
    fn resident_bytes_covers_weights_and_stream() {
        let plain = tinynet_program(false);
        let analytical = plain.grouped().graph.total_weight_bytes(plain.cfg().qw as u64);
        assert_eq!(
            plain.resident_bytes(),
            analytical + plain.stream().byte_size() as u64
        );
        let with_params = tinynet_program(true);
        let payload: u64 = with_params
            .params()
            .unwrap()
            .groups
            .values()
            .map(|g| (g.weights.len() + 4 * g.bias.len()) as u64
                + g.lut.as_ref().map_or(0, |l| l.len() as u64))
            .sum();
        assert_eq!(
            with_params.resident_bytes(),
            payload + with_params.stream().byte_size() as u64
        );
        assert!(with_params.resident_bytes() > 0);
    }

    #[test]
    fn corruption_is_rejected_typed() {
        let bytes = tinynet_program(false).to_bytes();
        // flip one payload byte -> checksum failure
        let mut bad = bytes.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0xFF;
        assert!(matches!(Program::from_bytes(&bad), Err(CompileError::Artifact(_))));
        // truncation
        assert!(matches!(
            Program::from_bytes(&bytes[..bytes.len() / 2]),
            Err(CompileError::Artifact(_))
        ));
        // not a program at all
        assert!(matches!(Program::from_bytes(b"junk"), Err(CompileError::Artifact(_))));
    }

    #[test]
    fn into_program_equals_pack() {
        let compiler = Compiler::new(AccelConfig::kcu1500_int8());
        let analyzed = compiler.analyze(&zoo::tinynet()).unwrap();
        let lowered = compiler
            .lower(&compiler.allocate(&compiler.optimize(&analyzed).unwrap()).unwrap())
            .unwrap();
        let packed = compiler.pack(&lowered).unwrap();
        let consumed = lowered.into_program(None).unwrap();
        assert_eq!(packed.to_bytes(), consumed.to_bytes());
    }

    #[test]
    fn params_inconsistent_with_stream_are_rejected() {
        let compiler = Compiler::new(AccelConfig::kcu1500_int8());
        let analyzed = compiler.analyze(&zoo::tinynet()).unwrap();
        let lowered = compiler
            .lower(&compiler.allocate(&compiler.optimize(&analyzed).unwrap()).unwrap())
            .unwrap();
        // the stream was lowered without params (quant_shift 0 encoded);
        // these params imply shift 7 on every weighted group, so packing
        // them alongside that stream would be a self-contradicting artifact
        let params = Params::random(&analyzed.grouped, 3);
        assert!(matches!(
            lowered.into_program(Some(params)),
            Err(CompileError::Artifact(_))
        ));
    }

    #[test]
    fn mismatched_parts_are_rejected() {
        let compiler = Compiler::new(AccelConfig::kcu1500_int8());
        let analyzed = compiler.analyze(&zoo::tinynet()).unwrap();
        let lowered = compiler
            .lower(&compiler.allocate(&compiler.optimize(&analyzed).unwrap()).unwrap())
            .unwrap();
        // wrong model name
        assert!(Program::from_parts(
            "NotTinyNet".into(),
            "cutpoint".into(),
            AccelConfig::kcu1500_int8(),
            lowered.grouped.clone(),
            lowered.alloc.assigns.clone(),
            lowered.stream.words.clone(),
            None,
        )
        .is_err());
        // truncated stream
        assert!(Program::from_parts(
            lowered.model.clone(),
            "cutpoint".into(),
            AccelConfig::kcu1500_int8(),
            lowered.grouped.clone(),
            lowered.alloc.assigns.clone(),
            lowered.stream.words[..11].to_vec(),
            None,
        )
        .is_err());
    }
}
