//! Binary envelope of the packed [`super::Program`] artifact.
//!
//! ```text
//! bytes 0..8    magic  "SFPROG01"
//! bytes 8..12   format version (u32 LE)
//! bytes 12..16  FNV-1a checksum of the payload (u32 LE)
//! bytes 16..24  payload length (u64 LE)
//! bytes 24..    payload: a sequence of u64-length-prefixed sections
//! ```
//!
//! The writer is fully deterministic (section order is fixed, the JSON
//! sections use the `BTreeMap`-backed writer, parameters are emitted in
//! sorted group order), so `save → load → save` is byte-identical — the
//! property `rust/tests/program_roundtrip.rs` checks for every zoo model.

use crate::compiler::CompileError;
use crate::Result;

/// Envelope magic: "ShortcutFusion PROGram", format generation 01.
pub const MAGIC: [u8; 8] = *b"SFPROG01";

/// Bump on any incompatible change to the payload layout.
pub const FORMAT_VERSION: u32 = 1;

const HEADER_LEN: usize = 24;

/// 32-bit FNV-1a over a byte slice — the artifact's integrity checksum.
/// Not cryptographic; it guards against truncation and bit-rot, exactly
/// like the magic tag in instruction word 10 guards single instructions.
pub fn fnv1a32(bytes: &[u8]) -> u32 {
    let mut h: u32 = 0x811C_9DC5;
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

/// Prepend the header (magic, version, checksum, length) to a payload.
pub fn wrap(payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
    out.extend_from_slice(&fnv1a32(payload).to_le_bytes());
    out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    out.extend_from_slice(payload);
    out
}

/// Validate the header and return the checksummed payload.
pub fn unwrap(bytes: &[u8]) -> Result<&[u8]> {
    if bytes.len() < HEADER_LEN {
        return Err(CompileError::artifact(format!(
            "{} bytes is too short for a program header",
            bytes.len()
        )));
    }
    if bytes[0..8] != MAGIC {
        return Err(CompileError::artifact("bad magic — not a packed program"));
    }
    let version = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
    if version != FORMAT_VERSION {
        return Err(CompileError::artifact(format!(
            "format version {version} (this build reads {FORMAT_VERSION})"
        )));
    }
    let checksum = u32::from_le_bytes(bytes[12..16].try_into().unwrap());
    let len = u64::from_le_bytes(bytes[16..24].try_into().unwrap());
    let payload = &bytes[HEADER_LEN..];
    if len != payload.len() as u64 {
        return Err(CompileError::artifact(format!(
            "payload length {} does not match header ({len})",
            payload.len()
        )));
    }
    let actual = fnv1a32(payload);
    if actual != checksum {
        return Err(CompileError::artifact(format!(
            "checksum mismatch: stored {checksum:#010x}, computed {actual:#010x}"
        )));
    }
    Ok(payload)
}

/// Appends u64-length-prefixed sections to a payload buffer.
#[derive(Default)]
pub struct SectionWriter {
    buf: Vec<u8>,
}

impl SectionWriter {
    /// An empty payload.
    pub fn new() -> Self {
        SectionWriter { buf: Vec::new() }
    }

    /// Append one length-prefixed section.
    pub fn section(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(&(bytes.len() as u64).to_le_bytes());
        self.buf.extend_from_slice(bytes);
    }

    /// Append unframed bytes (fixed-width fields; the read-side mirror is
    /// [`SectionReader::raw`]).
    pub fn raw(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// The assembled payload.
    pub fn finish(self) -> Vec<u8> {
        self.buf
    }
}

/// Sequential reader over a [`SectionWriter`] payload; every read is
/// bounds-checked so a truncated or corrupted artifact fails typed.
pub struct SectionReader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> SectionReader<'a> {
    /// A reader positioned at the start of `bytes`.
    pub fn new(bytes: &'a [u8]) -> Self {
        SectionReader { bytes, pos: 0 }
    }

    /// Read exactly `n` raw bytes.
    pub fn raw(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.bytes.len())
            .ok_or_else(|| CompileError::artifact("truncated artifact"))?;
        let out = &self.bytes[self.pos..end];
        self.pos = end;
        Ok(out)
    }

    /// Read one u64-length-prefixed section.
    pub fn section(&mut self) -> Result<&'a [u8]> {
        let len = u64::from_le_bytes(self.raw(8)?.try_into().unwrap());
        let len = usize::try_from(len)
            .map_err(|_| CompileError::artifact("section length overflows usize"))?;
        self.raw(len)
    }

    /// True once every payload byte has been consumed.
    pub fn done(&self) -> bool {
        self.pos == self.bytes.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wrap_unwrap_round_trip() {
        let payload = b"hello sections".to_vec();
        let bytes = wrap(&payload);
        assert_eq!(unwrap(&bytes).unwrap(), payload.as_slice());
    }

    #[test]
    fn every_corrupted_byte_is_detected() {
        let mut w = SectionWriter::new();
        w.section(b"abc");
        w.section(b"defgh");
        let bytes = wrap(&w.finish());
        for i in 0..bytes.len() {
            let mut bad = bytes.clone();
            bad[i] ^= 0x40;
            assert!(unwrap(&bad).is_err(), "flip at byte {i} went unnoticed");
        }
    }

    #[test]
    fn truncation_is_detected() {
        let bytes = wrap(b"payload");
        assert!(unwrap(&bytes[..bytes.len() - 1]).is_err());
        assert!(unwrap(&bytes[..4]).is_err());
    }

    #[test]
    fn sections_read_back_in_order() {
        let mut w = SectionWriter::new();
        w.section(b"one");
        w.section(b"");
        w.section(&[1, 2, 3, 4]);
        let payload = w.finish();
        let mut r = SectionReader::new(&payload);
        assert_eq!(r.section().unwrap(), b"one");
        assert_eq!(r.section().unwrap(), b"");
        assert_eq!(r.section().unwrap(), &[1, 2, 3, 4]);
        assert!(r.done());
    }

    #[test]
    fn reader_rejects_overlong_section() {
        let mut payload = Vec::new();
        payload.extend_from_slice(&u64::MAX.to_le_bytes());
        let mut r = SectionReader::new(&payload);
        assert!(r.section().is_err());
    }

    #[test]
    fn fnv_is_stable() {
        // reference vectors for the 32-bit FNV-1a parameters
        assert_eq!(fnv1a32(b""), 0x811C_9DC5);
        assert_eq!(fnv1a32(b"a"), 0xE40C_292C);
    }
}
