//! Instruction-level traffic replay.
//!
//! Walks the *lowered instruction stream* (not the graph) against a
//! modeled memory system — three physical buffers + the DRAM arena —
//! counting every byte that crosses the chip boundary. This closes the
//! verification loop between the optimizer's analytical DRAM model
//! (eqs. 8–9, computed from the graph) and what the accelerator would
//! actually issue when executing the packed program: the two must agree
//! exactly (`traffic_matches_analytical_model` below is run for every
//! zoo network in the test suite).

use crate::analyzer::{GroupKind, GroupedGraph};
use crate::config::AccelConfig;
use crate::isa::{Instruction, InstructionStream, Opcode};
use crate::telemetry::ClassBytes;

/// Byte counters from a replay.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TrafficCount {
    /// Feature-map bytes read from DRAM.
    pub fm_read: u64,
    /// Feature-map bytes written to DRAM.
    pub fm_write: u64,
    /// Weight bytes fetched.
    pub weight_read: u64,
    /// On-chip buffer bytes read (for the energy model's SRAM term).
    pub buf_read: u64,
    /// On-chip buffer bytes written.
    pub buf_write: u64,
    /// Per-tensor-class attribution of the DRAM counters, recovered from
    /// the packed ISA fields alone. Invariants:
    /// `classes.ifm + classes.shortcut == fm_read`,
    /// `classes.ofm == fm_write`, `classes.weights == weight_read`, so
    /// `classes.total() == dram_total()`.
    pub classes: ClassBytes,
}

impl TrafficCount {
    /// Feature-map bytes crossing the chip boundary (reads + writes).
    pub fn fm_total(&self) -> u64 {
        self.fm_read + self.fm_write
    }

    /// All DRAM bytes: feature maps + weights.
    pub fn dram_total(&self) -> u64 {
        self.fm_total() + self.weight_read
    }
}

/// Replay one instruction's memory behaviour.
fn replay_instr(
    ins: &Instruction,
    gg: &GroupedGraph,
    gi: usize,
    cfg: &AccelConfig,
    t: &mut TrafficCount,
) {
    let qa = cfg.qa as u64;
    let gr = &gg.groups[gi];
    let in_bytes = gr.in_shape.bytes(cfg.qa) as u64;
    let out_bytes = gr.out_shape.bytes(cfg.qa) as u64;

    if matches!(ins.opcode, Opcode::Input) {
        return;
    }
    // Concat is pure redirection: producers already placed the data.
    if matches!(ins.opcode, Opcode::Concat) {
        return;
    }

    // weights stream exactly once per instruction
    t.weight_read += ins.weight_bytes as u64;
    t.classes.weights += ins.weight_bytes as u64;

    // main operand
    let vector_in = gr.in_shape.h * gr.in_shape.w == 1;
    if !vector_in {
        if ins.in_sel == 3 {
            t.fm_read += in_bytes;
            t.classes.ifm += in_bytes;
        } else {
            t.buf_read += in_bytes;
        }
    }
    // second operand (fused shortcut / scale gate / eltwise second)
    if ins.fused_eltwise || matches!(ins.opcode, Opcode::Scale | Opcode::Eltwise) {
        if let Some(src) = gr.shortcut_of.or_else(|| gr.inputs.get(1).copied()) {
            let src_gr = &gg.groups[src.0];
            let aux_bytes = src_gr.out_shape.bytes(cfg.qa) as u64;
            let aux_vec = src_gr.out_shape.h * src_gr.out_shape.w == 1;
            if !aux_vec {
                if ins.aux_sel == 3 {
                    t.fm_read += aux_bytes;
                    // same classification rule as the analytical model:
                    // a residual shortcut read vs. a plain second input
                    if gr.shortcut_of.is_some() {
                        t.classes.shortcut += aux_bytes;
                    } else {
                        t.classes.ifm += aux_bytes;
                    }
                } else {
                    t.buf_read += aux_bytes;
                }
            }
        }
    }
    // output
    let vector_out = gr.out_shape.h * gr.out_shape.w == 1;
    if !vector_out {
        if ins.out_sel == 3 {
            t.fm_write += out_bytes;
            t.classes.ofm += out_bytes;
        } else {
            t.buf_write += out_bytes;
        }
    }
    let _ = qa;
}

/// Replay a whole program.
///
/// `staged_inputs[i]` / `also_dram[i]` mirror the allocator flags that are
/// not encoded in the 11 instruction words (the hardware performs the
/// staging DMA as part of the group prologue; the flags travel in the
/// packed header in a real deployment).
///
/// Tile streaming is recovered from the stream itself
/// ([`crate::tile::TilePlan::from_stream`]): per-instruction placements
/// already count the base traffic, so the replay adds exactly the
/// [`crate::tile::overheads`] terms — halo re-reads on `fm_read`,
/// per-tile weight re-streams on `weight_read` — the same terms the
/// analytical model folds into eq. (8)/(9).
pub fn replay(
    gg: &GroupedGraph,
    stream: &InstructionStream,
    staged_inputs: &[bool],
    also_dram: &[bool],
    cfg: &AccelConfig,
) -> TrafficCount {
    assert_eq!(stream.instrs.len(), gg.groups.len());
    let mut t = TrafficCount::default();
    for (gi, ins) in stream.instrs.iter().enumerate() {
        replay_instr(ins, gg, gi, cfg, &mut t);
        let gr = &gg.groups[gi];
        if staged_inputs[gi] {
            // the staging DMA: one DRAM read of the input into a buffer
            t.fm_read += gr.in_shape.bytes(cfg.qa) as u64;
            t.classes.ifm += gr.in_shape.bytes(cfg.qa) as u64;
            // the streamed buffer read was already counted as buf_read;
            // undo the double-counted DRAM read if in_sel was on-chip
            if ins.in_sel != 3 {
                t.buf_write += gr.in_shape.bytes(cfg.qa) as u64;
            }
        }
        if also_dram[gi] {
            t.fm_write += gr.out_shape.bytes(cfg.qa) as u64;
            t.classes.ofm += gr.out_shape.bytes(cfg.qa) as u64;
        }
        if gr.kind == GroupKind::Input {
            continue;
        }
    }
    let plan = crate::tile::TilePlan::from_stream(stream);
    if !plan.is_empty() {
        let o = crate::tile::overheads(gg, cfg, &plan);
        t.fm_read += o.halo_fm_extra;
        t.classes.ifm += o.halo_fm_extra;
        t.weight_read += o.weight_extra;
        t.classes.weights += o.weight_extra;
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::allocate;
    use crate::analyzer::analyze;
    use crate::compiler::Compiler;
    use crate::optimizer::dram_access;
    use crate::zoo;

    /// The keystone cross-check: instruction-level replay must reproduce
    /// the analytical eq-8/9 model byte-for-byte (minus spill traffic,
    /// which the analytical model accounts separately).
    #[test]
    fn traffic_matches_analytical_model() {
        let cfg = crate::config::AccelConfig::kcu1500_int8();
        for &name in zoo::MODEL_NAMES {
            let g = zoo::by_name(name, zoo::default_input(name)).unwrap();
            let r = Compiler::new(cfg.clone()).compile(&g).unwrap();
            let alloc = allocate(&r.grouped, &r.evaluation.policy, &cfg);
            let staged: Vec<bool> = alloc.assigns.iter().map(|a| a.staged_input).collect();
            let also: Vec<bool> = alloc.assigns.iter().map(|a| a.also_dram).collect();
            let replayed = replay(&r.grouped, &r.stream, &staged, &also, &cfg);
            let analytical = dram_access(&r.grouped, &r.evaluation.policy, &alloc, &cfg);
            assert_eq!(
                replayed.fm_total() + analytical.spill_bytes,
                analytical.fm_bytes,
                "{name}: replayed {} + spills {} != analytical {}",
                replayed.fm_total(),
                analytical.spill_bytes,
                analytical.fm_bytes
            );
            assert_eq!(replayed.weight_read, analytical.weight_bytes, "{name}: weights");
        }
    }

    #[test]
    fn replay_classes_partition_dram_counters() {
        // The class attribution recovered from packed ISA fields must
        // partition the flat replay counters for every zoo program.
        let cfg = crate::config::AccelConfig::kcu1500_int8();
        for &name in zoo::MODEL_NAMES {
            let g = zoo::by_name(name, zoo::default_input(name)).unwrap();
            let r = Compiler::new(cfg.clone()).compile(&g).unwrap();
            let alloc = allocate(&r.grouped, &r.evaluation.policy, &cfg);
            let staged: Vec<bool> = alloc.assigns.iter().map(|a| a.staged_input).collect();
            let also: Vec<bool> = alloc.assigns.iter().map(|a| a.also_dram).collect();
            let t = replay(&r.grouped, &r.stream, &staged, &also, &cfg);
            assert_eq!(t.classes.ifm + t.classes.shortcut, t.fm_read, "{name}: reads");
            assert_eq!(t.classes.ofm, t.fm_write, "{name}: writes");
            assert_eq!(t.classes.weights, t.weight_read, "{name}: weights");
            assert_eq!(t.classes.total(), t.dram_total(), "{name}: total");
        }
    }

    #[test]
    fn weights_counted_exactly_once() {
        let cfg = crate::config::AccelConfig::kcu1500_int8();
        let g = zoo::resnet50(224);
        let r = Compiler::new(cfg.clone()).compile(&g).unwrap();
        let alloc = allocate(&r.grouped, &r.evaluation.policy, &cfg);
        let staged: Vec<bool> = alloc.assigns.iter().map(|a| a.staged_input).collect();
        let also: Vec<bool> = alloc.assigns.iter().map(|a| a.also_dram).collect();
        let t = replay(&r.grouped, &r.stream, &staged, &also, &cfg);
        assert_eq!(t.weight_read, g.total_weight_bytes(cfg.qw as u64));
    }

    #[test]
    fn buffer_traffic_dominates_for_frame_policies() {
        // in an all-frame run, on-chip traffic must dwarf DRAM traffic —
        // the energy argument of [37]
        let cfg = crate::config::AccelConfig::kcu1500_int8();
        let g = zoo::resnet50(224);
        let gg = analyze(&g);
        let policy = vec![crate::isa::ReuseMode::Frame; gg.groups.len()];
        let alloc = allocate(&gg, &policy, &cfg);
        let layout = crate::alloc::layout(&gg, &policy, &alloc, &cfg);
        let assigns: Vec<crate::isa::MemAssign> = gg
            .groups
            .iter()
            .enumerate()
            .map(|(gi, gr)| crate::isa::MemAssign {
                reuse: policy[gi],
                in_loc: match alloc.assigns[gi].in_loc {
                    crate::alloc::Loc::Buf(b) => crate::isa::MemLoc::Buf(b),
                    _ => crate::isa::MemLoc::Dram(layout.fmaps[gi].offset),
                },
                out_loc: match alloc.assigns[gi].out_loc {
                    crate::alloc::Loc::Buf(b) => crate::isa::MemLoc::Buf(b),
                    _ => crate::isa::MemLoc::Dram(layout.fmaps[gi].offset),
                },
                aux_loc: alloc.assigns[gi].aux_loc.map(|l| match l {
                    crate::alloc::Loc::Buf(b) => crate::isa::MemLoc::Buf(b),
                    _ => crate::isa::MemLoc::Dram(0),
                }),
                weight_addr: 0,
                weight_bytes: gr.weight_bytes(&gg.graph, cfg.qw as u64) as u32,
                quant_shift: 0,
                ..Default::default()
            })
            .collect();
        let stream = crate::isa::lower(&gg, &assigns);
        let staged: Vec<bool> = alloc.assigns.iter().map(|a| a.staged_input).collect();
        let also: Vec<bool> = alloc.assigns.iter().map(|a| a.also_dram).collect();
        let t = replay(&gg, &stream, &staged, &also, &cfg);
        assert!(
            t.buf_read + t.buf_write > 10 * t.fm_total(),
            "on-chip {} vs DRAM {}",
            t.buf_read + t.buf_write,
            t.fm_total()
        );
    }
}
