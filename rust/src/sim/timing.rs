//! Sequential network walk: per-group latency with memory overlap.

use super::macarray::compute_cycles;
use crate::alloc::{AllocResult, Loc};
use crate::analyzer::{GroupKind, GroupedGraph};
use crate::config::AccelConfig;
use crate::isa::ReuseMode;

/// Cycle breakdown for one group.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GroupTiming {
    /// Pure MAC-array compute cycles.
    pub compute_cycles: u64,
    /// Feature-map DRAM stream cycles (reads + writes during compute).
    pub stream_cycles: u64,
    /// Weight-fetch cycles (row-reuse preload / frame-reuse stream).
    pub weight_cycles: u64,
    /// Pipeline fill (row-buffer warm-up before the first window).
    pub fill_cycles: u64,
    /// Resulting group latency after overlap.
    pub latency_cycles: u64,
}

/// Whole-network timing result.
#[derive(Debug, Clone)]
pub struct NetworkTiming {
    /// Cycle breakdown per group, in program order.
    pub per_group: Vec<GroupTiming>,
    /// End-to-end cycles for one inference.
    pub total_cycles: u64,
    /// End-to-end latency at the configured clock, ms.
    pub latency_ms: f64,
    /// Average GOPS (the paper's Tables II/V/VII row).
    pub gops: f64,
    /// DSP / MAC efficiency = average GOPS / peak GOPS.
    pub mac_efficiency: f64,
}

/// Simulate the instruction stream timing for one policy.
///
/// Model (per group, in program order):
/// * compute = MAC-array cycles ([`compute_cycles`]);
/// * streaming feature-map DRAM traffic overlaps compute (the wide
///   circular row buffer / write buffer decouple the two) — a group's
///   latency is `max(compute, stream)`;
/// * **frame-reuse** weights stream during compute and are "hidden by the
///   computation of the sub-frame input" (§II) — folded into the max;
/// * **row-reuse** whole-layer weight preloads overlap the *previous*
///   group's execution (double weight buffer); any preload not covered
///   by the previous group's latency stalls the pipeline;
/// * a pipeline-fill term charges the `K+1`-row warm-up of the circular
///   row buffer at DRAM speed for row-reuse groups whose input streams
///   from DRAM.
pub fn simulate(
    gg: &GroupedGraph,
    policy: &[ReuseMode],
    alloc: &AllocResult,
    cfg: &AccelConfig,
) -> NetworkTiming {
    simulate_with_tiles(gg, policy, alloc, cfg, None)
}

/// [`simulate`] extended for depth-first tile streaming. With
/// `plan: None` (or an empty plan) this is *exactly* the whole-frame
/// model above. Groups inside a tiled region instead:
/// * scale compute by the halo overcompute
///   (`rows_out_total / out_h`, from [`crate::tile::region_profile`]);
/// * stream the region-first input with its re-read halo rows and
///   out-of-region shortcut tiles with theirs (interior operands are
///   on-chip after [`crate::tile::apply_overlay`] and stream nothing);
/// * stream weights once per tile when the plan marks them streamed
///   (`n_tiles × W`), overlapped with compute like frame-reuse — so
///   they drop out of the row-reuse preload look-ahead;
/// * skip the row-buffer warm-up fill (tiles prime their own slabs).
pub fn simulate_with_tiles(
    gg: &GroupedGraph,
    policy: &[ReuseMode],
    alloc: &AllocResult,
    cfg: &AccelConfig,
    plan: Option<&crate::tile::TilePlan>,
) -> NetworkTiming {
    assert_eq!(policy.len(), gg.groups.len());
    // group index -> (region index, index within the region)
    let mut tile_of: Vec<Option<(usize, usize)>> = vec![None; gg.groups.len()];
    let mut profiles = Vec::new();
    let mut regions: Vec<&crate::tile::TileRegion> = Vec::new();
    if let Some(plan) = plan {
        for (ri, region) in plan.regions.iter().enumerate() {
            profiles.push(crate::tile::region_profile(gg, region));
            regions.push(region);
            for g in region.first..=region.last {
                tile_of[g] = Some((ri, g - region.first));
            }
        }
    }
    let bpc = cfg.dram_bytes_per_cycle();
    let qa = cfg.qa;
    let mut per_group = Vec::with_capacity(gg.groups.len());
    let mut total: u64 = 0;
    // Row-reuse weight preload that must overlap the previous group.
    let mut pending_preload: u64 = 0;

    for (gi, gr) in gg.groups.iter().enumerate() {
        if gr.kind == GroupKind::Input {
            per_group.push(GroupTiming {
                compute_cycles: 0,
                stream_cycles: 0,
                weight_cycles: 0,
                fill_cycles: 0,
                latency_cycles: 0,
            });
            continue;
        }
        let a = &alloc.assigns[gi];
        let tiled = tile_of[gi].map(|(ri, idx)| (regions[ri], &profiles[ri], idx));
        let mut compute = compute_cycles(gg, gr, cfg);
        if let Some((_, p, idx)) = tiled {
            // halo overcompute: tiles overlap, so interior rows recompute
            let out_h = gr.out_shape.h.max(1) as u64;
            compute = (compute * p.rows_out_total[idx]).div_ceil(out_h);
        }

        // ---- feature-map DRAM streaming --------------------------------
        let mut stream_bytes: u64 = 0;
        if gr.kind != GroupKind::Concat {
            if a.in_loc == Loc::Dram || a.staged_input {
                stream_bytes += gr.in_shape.bytes(qa) as u64;
            }
            if let Some(Loc::Dram) = a.aux_loc {
                let src = gr.shortcut_of.or_else(|| gr.inputs.get(1).copied());
                if let Some(src) = src {
                    stream_bytes += gg.groups[src.0].out_shape.bytes(qa) as u64;
                }
            }
            if a.out_loc == Loc::Dram {
                stream_bytes += gr.out_shape.bytes(qa) as u64;
            }
        }
        if a.also_dram {
            stream_bytes += gr.out_shape.bytes(qa) as u64;
        }
        if let Some((region, p, idx)) = tiled {
            // re-read halos on the two operands that still cross DRAM
            if gi == region.first && (a.in_loc == Loc::Dram || a.staged_input) {
                let in_row = (gr.in_shape.w * gr.in_shape.c * qa) as u64;
                stream_bytes +=
                    (p.rows_in_total * in_row).saturating_sub(gr.in_shape.bytes(qa) as u64);
            }
            if p.rows_aux_total[idx] > 0 {
                if let Some(src) = gr.shortcut_of.or_else(|| gr.inputs.get(1).copied()) {
                    let so = gg.groups[src.0].out_shape;
                    let row = (so.w * so.c * qa) as u64;
                    stream_bytes +=
                        (p.rows_aux_total[idx] * row).saturating_sub(so.bytes(qa) as u64);
                }
            }
        }
        let stream = (stream_bytes as f64 / bpc).ceil() as u64;

        // ---- weights ----------------------------------------------------
        let mut weight_bytes = gr.weight_bytes(&gg.graph, cfg.qw as u64);
        if let Some((region, p, idx)) = tiled {
            if region.streamed_weights[idx] {
                weight_bytes *= p.n_tiles as u64;
            }
        }
        let weight_cycles = (weight_bytes as f64 / bpc).ceil() as u64;

        // ---- pipeline fill ----------------------------------------------
        let (k, _s, _dw) = gr.conv_geometry(&gg.graph);
        let fill = if tiled.is_none()
            && policy[gi] == ReuseMode::Row
            && (a.in_loc == Loc::Dram)
            && matches!(gr.kind, GroupKind::Conv | GroupKind::DwConv)
        {
            let row_bytes = (gr.in_shape.w * gr.in_shape.c * qa) as u64;
            ((k as u64 + 1) * row_bytes) as u64 / bpc as u64
        } else {
            0
        };

        let latency = if tiled.is_some() {
            // tile loop: weights (resident preload or per-tile chunks)
            // overlap compute like frame-reuse; consume any stray stall
            let stall = pending_preload;
            pending_preload = 0;
            compute.max(stream).max(weight_cycles) + stall
        } else {
            match policy[gi] {
                ReuseMode::Frame => {
                    // weights stream during compute (double weight-block buffer)
                    compute.max(stream).max(weight_cycles) + fill
                }
                ReuseMode::Row => {
                    // whole-layer preload overlapped with the previous group
                    let body = compute.max(stream);
                    let stall = pending_preload; // set by the previous group
                    pending_preload = 0;
                    body + stall + fill
                }
            }
        };

        // This group's weights (if row-reuse) preload during the previous
        // group; compute the *next* pending amount: what didn't fit.
        if policy[gi] == ReuseMode::Row {
            // the preload we just consumed belonged to this group;
            // compute how much of the NEXT row group's preload this
            // group's execution hides (done in the next iteration via
            // `latency` bookkeeping below).
        }
        // Look ahead: if the next group is row-reuse, its preload overlaps
        // this group's latency. Tiled groups opt out — their weights are
        // charged inside the tile loop above.
        if let Some(next) = gg.groups.get(gi + 1) {
            if policy[gi + 1] == ReuseMode::Row && tile_of[gi + 1].is_none() {
                let next_w = next.weight_bytes(&gg.graph, cfg.qw as u64);
                let next_cycles = (next_w as f64 / bpc).ceil() as u64;
                pending_preload = next_cycles.saturating_sub(latency);
            }
        }

        total += latency;
        per_group.push(GroupTiming {
            compute_cycles: compute,
            stream_cycles: stream,
            weight_cycles,
            fill_cycles: fill,
            latency_cycles: latency,
        });
    }

    let latency_ms = total as f64 / (cfg.freq_mhz * 1e3);
    let gop = gg.graph.total_gop();
    let gops = gop / (latency_ms / 1e3);
    NetworkTiming {
        per_group,
        total_cycles: total,
        latency_ms,
        gops,
        mac_efficiency: gops / cfg.peak_gops(),
    }
}

/// The *naive fixed row-based* baseline of Fig. 16: the scheme of Fig.
/// 3(b) without the whole-layer weight buffer — each weight block is
/// re-fetched per output row (Table I: "Weight reads: H"), and all
/// feature maps stream through DRAM. This is the comparison line for the
/// 2.17× speed-up claim, NOT the proposed design's row-reuse mode (which
/// preloads weights once, eq. 1).
pub fn simulate_fixed_row_baseline(gg: &GroupedGraph, cfg: &AccelConfig) -> NetworkTiming {
    let bpc = cfg.dram_bytes_per_cycle();
    let qa = cfg.qa;
    let mut per_group = Vec::with_capacity(gg.groups.len());
    let mut total: u64 = 0;
    for gr in &gg.groups {
        if gr.kind == GroupKind::Input || gr.kind == GroupKind::Concat {
            per_group.push(GroupTiming {
                compute_cycles: 0,
                stream_cycles: 0,
                weight_cycles: 0,
                fill_cycles: 0,
                latency_cycles: 0,
            });
            continue;
        }
        let compute = compute_cycles(gg, gr, cfg);
        let mut stream_bytes = gr.in_shape.bytes(qa) as u64 + gr.out_shape.bytes(qa) as u64;
        if let Some(src) = gr.shortcut_of {
            stream_bytes += gg.groups[src.0].out_shape.bytes(qa) as u64;
        }
        let h = gr.out_shape.h as u64;
        let weight_bytes = gr.weight_bytes(&gg.graph, cfg.qw as u64) * h.max(1);
        let mem = ((stream_bytes + weight_bytes) as f64 / bpc).ceil() as u64;
        let stream = (stream_bytes as f64 / bpc).ceil() as u64;
        let weight_cycles = (weight_bytes as f64 / bpc).ceil() as u64;
        let latency = compute.max(mem);
        total += latency;
        per_group.push(GroupTiming {
            compute_cycles: compute,
            stream_cycles: stream,
            weight_cycles,
            fill_cycles: 0,
            latency_cycles: latency,
        });
    }
    let latency_ms = total as f64 / (cfg.freq_mhz * 1e3);
    let gop = gg.graph.total_gop();
    let gops = gop / (latency_ms / 1e3);
    NetworkTiming {
        per_group,
        total_cycles: total,
        latency_ms,
        gops,
        mac_efficiency: gops / cfg.peak_gops(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alloc::allocate;
    use crate::analyzer::analyze;
    use crate::zoo;

    fn run(name: &str, input: usize, mode: ReuseMode) -> NetworkTiming {
        let gg = analyze(&zoo::by_name(name, input).unwrap());
        let cfg = AccelConfig::kcu1500_int8();
        let policy = vec![mode; gg.groups.len()];
        let alloc = allocate(&gg, &policy, &cfg);
        simulate(&gg, &policy, &alloc, &cfg)
    }

    #[test]
    fn resnet152_latency_matches_table5_scale() {
        // Table V: ResNet152@256 → 26.78 ms, 1163 GOPS, 71 % efficiency.
        let t = run("resnet152", 256, ReuseMode::Frame);
        assert!(
            (15.0..40.0).contains(&t.latency_ms),
            "latency {} ms vs paper 26.78",
            t.latency_ms
        );
        assert!(
            (0.50..0.95).contains(&t.mac_efficiency),
            "eff {} vs paper 0.71",
            t.mac_efficiency
        );
    }

    #[test]
    fn efficientnet_efficiency_is_low() {
        // Table V: EfficientNet-B1@256 → 4.69 ms, 19.4 % MAC efficiency —
        // depthwise + SE structurally underuse the array.
        let t = run("efficientnet-b1", 256, ReuseMode::Frame);
        assert!(
            (0.05..0.35).contains(&t.mac_efficiency),
            "eff {} vs paper 0.19",
            t.mac_efficiency
        );
        assert!((1.0..15.0).contains(&t.latency_ms), "latency {}", t.latency_ms);
    }

    #[test]
    fn frame_mode_beats_row_mode_when_buffers_fit() {
        // Fig 16(c): 2.17× speed-up over fixed row-based reuse (YOLOv2).
        let row = run("yolov2", 416, ReuseMode::Row);
        let frame = run("yolov2", 416, ReuseMode::Frame);
        assert!(
            frame.latency_ms < row.latency_ms,
            "frame {} !< row {}",
            frame.latency_ms,
            row.latency_ms
        );
    }

    #[test]
    fn yolov3_scale() {
        // Table V: YOLOv3@416 → 57.57 ms.
        let t = run("yolov3", 416, ReuseMode::Frame);
        assert!((30.0..90.0).contains(&t.latency_ms), "latency {}", t.latency_ms);
    }

    #[test]
    fn with_tiles_none_is_exactly_simulate() {
        let cfg = AccelConfig::kcu1500_int8();
        for &name in zoo::MODEL_NAMES {
            let gg = analyze(&zoo::by_name(name, zoo::default_input(name)).unwrap());
            for mode in [ReuseMode::Row, ReuseMode::Frame] {
                let policy = vec![mode; gg.groups.len()];
                let alloc = allocate(&gg, &policy, &cfg);
                let a = simulate(&gg, &policy, &alloc, &cfg);
                let b = simulate_with_tiles(&gg, &policy, &alloc, &cfg, None);
                assert_eq!(a.total_cycles, b.total_cycles, "{name} {mode:?}");
            }
        }
    }

    #[test]
    fn tiled_timing_is_finite_and_drops_interior_streaming() {
        let gg = analyze(&zoo::vgg16_conv(224));
        let mut cfg = AccelConfig::kcu1500_int8();
        cfg.sram_budget = 1_000_000;
        let plan = crate::tile::plan(&gg, &cfg, 8);
        assert!(!plan.is_empty());
        let policy = vec![ReuseMode::Row; gg.groups.len()];
        let mut alloc = allocate(&gg, &policy, &cfg);
        crate::tile::apply_overlay(&mut alloc.assigns, &gg, &plan);
        let t = simulate_with_tiles(&gg, &policy, &alloc, &cfg, Some(&plan));
        assert!(t.latency_ms.is_finite() && t.latency_ms > 0.0);
        // interior region groups stream no feature maps from DRAM
        for r in &plan.regions {
            for g in r.first + 1..r.last {
                let gr = &gg.groups[g];
                if gr.shortcut_of.is_none() && gr.inputs.len() < 2 {
                    assert_eq!(t.per_group[g].stream_cycles, 0, "group {g} streams");
                }
            }
        }
    }

    #[test]
    fn total_is_sum_of_groups() {
        let t = run("resnet50", 256, ReuseMode::Frame);
        let sum: u64 = t.per_group.iter().map(|g| g.latency_cycles).sum();
        assert_eq!(sum, t.total_cycles);
    }

    #[test]
    fn latency_positive_and_finite_for_all_models() {
        for &name in zoo::MODEL_NAMES {
            for mode in [ReuseMode::Row, ReuseMode::Frame] {
                let t = run(name, zoo::default_input(name), mode);
                assert!(t.latency_ms.is_finite() && t.latency_ms > 0.0, "{name}");
                assert!(t.mac_efficiency <= 1.0, "{name} {mode:?}: eff {}", t.mac_efficiency);
            }
        }
    }
}
