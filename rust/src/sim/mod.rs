//! Cycle-accurate timing simulator.
//!
//! "A problem is raised such that the latency estimation by running the
//! RTL simulation for each candidate takes a very long time. [...]
//! Therefore, this work built a cycle-accurate timing simulator to
//! estimate the latency of a CNN layer running different reuse schemes"
//! (§IV-B). This module *is* that simulator: a per-group cycle model of
//! the shared-MAC-array datapath (Fig. 8) and the DRAM channel, walked
//! sequentially with weight-preload overlap, exactly the tool the
//! authors used to drive the optimizer and verify against RTL.

mod macarray;
mod timing;
mod traffic;

pub use macarray::{compute_cycles, dw_taps_per_unit, MacGeometry};
pub use timing::{simulate, simulate_fixed_row_baseline, simulate_with_tiles, GroupTiming, NetworkTiming};
pub use traffic::{replay, TrafficCount};
