//! Shared-MAC-array compute model (Fig. 7/8).
//!
//! The array processes, per cycle:
//! * **normal conv** — one kernel tap for `Ti` input channels ×
//!   `To × mults_per_dsp` output kernels (the DSP48E2 double-INT8 trick
//!   shares each input activation between two weights, Fig. 7a);
//! * **depthwise conv** — `To` channels × up to 32 kernel taps on the two
//!   split arrays (Fig. 8a: "the MAC array is able to process a
//!   [≤ 5×5] kernel in one cycle"), with no input sharing (single-mult
//!   mode, Fig. 7b);
//! * **FC** — a 1×1 conv on a 1×1 frame (tiny tiles ⇒ the ceil losses
//!   that make SE blocks expensive on this datapath);
//! * **SE scale** — a 1×1 depthwise multiply (§IV-A).

use crate::analyzer::{Group, GroupKind, GroupedGraph};
use crate::config::AccelConfig;
use crate::graph::OpKind;

/// Compute-array geometry derived from the configuration.
#[derive(Debug, Clone, Copy)]
pub struct MacGeometry {
    /// Input-channel parallelism.
    pub ti: usize,
    /// Output-channel parallelism.
    pub to: usize,
    /// Output kernels evaluated concurrently (To × mults_per_dsp shares).
    pub normal_kernels_per_cycle: usize,
    /// Kernel taps per depthwise unit per cycle (32 on the split array).
    pub dw_taps: usize,
}

impl MacGeometry {
    /// Derive the geometry from a target configuration.
    pub fn from_config(cfg: &AccelConfig) -> Self {
        MacGeometry {
            ti: cfg.ti,
            to: cfg.to,
            normal_kernels_per_cycle: cfg.to * cfg.mults_per_dsp,
            dw_taps: dw_taps_per_unit(cfg),
        }
    }
}

/// Taps a depthwise MAC unit covers per cycle: the 2048-MAC array splits
/// into `To` per-channel units (Fig. 8b), each `dsp_mac / To` MACs wide.
pub fn dw_taps_per_unit(cfg: &AccelConfig) -> usize {
    (cfg.dsp_mac / cfg.to).max(1)
}

/// Cycles the MAC arrays + post-chain need for one group's compute,
/// independent of memory stalls.
///
/// The post-processing chain (pooling, element-wise, upsampling) runs in
/// lock-step with the writeback and "does not incur an additional timing
/// overhead" (§III-B-2) — fused post-ops are free; standalone
/// pool/eltwise/upsample/copy groups stream at `To` elements/cycle.
pub fn compute_cycles(gg: &GroupedGraph, gr: &Group, cfg: &AccelConfig) -> u64 {
    let ti = cfg.ti as u64;
    let to = cfg.to as u64;
    match gr.kind {
        GroupKind::Conv | GroupKind::DwConv => {
            let node = gg.graph.node(gr.main);
            let (k, depthwise) = match node.op {
                OpKind::Conv { k, depthwise, .. } => (k as u64, depthwise),
                _ => (1, false),
            };
            let out = node.out_shape;
            let pixels = (out.h * out.w) as u64;
            let n = node.in_shapes[0].c as u64;
            let m = out.c as u64;
            if depthwise {
                // To channels in parallel; ceil(k²/taps) cycles per pixel.
                let taps = dw_taps_per_unit(cfg) as u64;
                let kernel_cycles = (k * k).div_ceil(taps);
                pixels * m.div_ceil(to) * kernel_cycles
            } else {
                // one tap × Ti inputs × `kernels` outputs per cycle, with
                // Ti × kernels = dsp_mac × mults_per_dsp total mults
                // (4096 INT8 mults/cycle on 2048 shared MACs, §III-B-1).
                let kernels = (cfg.dsp_mac * cfg.mults_per_dsp / cfg.ti) as u64;
                pixels * (k * k) * n.div_ceil(ti) * m.div_ceil(kernels)
            }
        }
        GroupKind::Fc => {
            let node = gg.graph.node(gr.main);
            let n = node.in_shapes[0].c as u64;
            let m = node.out_shape.c as u64;
            let kernels = (cfg.dsp_mac * cfg.mults_per_dsp / cfg.ti) as u64;
            n.div_ceil(ti) * m.div_ceil(kernels)
        }
        GroupKind::Scale => {
            // 1×1 depthwise multiply: To channels per cycle.
            let s = gr.out_shape;
            (s.h * s.w) as u64 * (s.c as u64).div_ceil(to)
        }
        GroupKind::Pool | GroupKind::Eltwise | GroupKind::Upsample | GroupKind::Act => {
            // standalone post-chain op: streams To elements per cycle
            let s = gr.in_shape;
            (s.h * s.w) as u64 * (s.c as u64).div_ceil(to)
        }
        GroupKind::Concat | GroupKind::Input => 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analyzer::analyze;
    use crate::graph::{Activation, GraphBuilder, PadMode, Shape};

    fn cfg() -> AccelConfig {
        AccelConfig::kcu1500_int8()
    }

    fn single_conv(
        k: usize,
        in_c: usize,
        out_c: usize,
        hw: usize,
        depthwise: bool,
    ) -> (GroupedGraph, usize) {
        let mut b = GraphBuilder::new("t", Shape::new(hw, hw, in_c));
        let x = b.input_id();
        if depthwise {
            b.dwconv("c", x, k, 1, PadMode::Same);
        } else {
            b.conv("c", x, k, 1, out_c, PadMode::Same);
        }
        let gg = analyze(&b.finish());
        let gi = gg
            .groups
            .iter()
            .position(|g| matches!(g.kind, GroupKind::Conv | GroupKind::DwConv))
            .unwrap();
        (gg, gi)
    }

    #[test]
    fn normal_conv_hits_4096_mults_per_cycle() {
        // 3x3, 64→128 channels over 16x16: macs = 16²·9·64·128.
        let (gg, gi) = single_conv(3, 64, 128, 16, false);
        let cycles = compute_cycles(&gg, &gg.groups[gi], &cfg());
        // 64 inputs × 64 kernels = 4096 mults/cycle ⇒ 256·9·1·2 cycles.
        assert_eq!(cycles, 256 * 9 * 2);
        let macs = gg.groups[gi].macs(&gg.graph);
        assert_eq!(macs / cycles, 4096); // full MXU-equivalent utilization
    }

    #[test]
    fn ceil_losses_show_up_for_small_channel_counts() {
        // 3 input channels still burn a full Ti=64 slot (first layers).
        let (gg, gi) = single_conv(3, 3, 64, 16, false);
        let cycles = compute_cycles(&gg, &gg.groups[gi], &cfg());
        let macs = gg.groups[gi].macs(&gg.graph);
        let eff = macs as f64 / (cycles as f64 * 4096.0);
        assert!(eff < 0.06, "eff {eff}"); // 3/64 ≈ 4.7 %
    }

    #[test]
    fn depthwise_3x3_one_cycle_per_pixel_per_64ch() {
        let (gg, gi) = single_conv(3, 64, 64, 16, true);
        let cycles = compute_cycles(&gg, &gg.groups[gi], &cfg());
        // 9 taps ≤ 32 ⇒ 1 cycle per pixel per 64-channel tile
        assert_eq!(cycles, 256);
        // utilization 9·64 / 2048 = 28 % — the EfficientNet story
        let macs = gg.groups[gi].macs(&gg.graph);
        let eff = macs as f64 / (cycles as f64 * 2048.0);
        assert!((eff - 0.28125).abs() < 1e-9);
    }

    #[test]
    fn depthwise_7x7_needs_two_cycles() {
        let (gg, gi) = single_conv(7, 64, 64, 16, true);
        let cycles = compute_cycles(&gg, &gg.groups[gi], &cfg());
        assert_eq!(cycles, 256 * 2); // 49 taps / 32 = 2 cycles
    }

    #[test]
    fn fc_pays_tile_quantization() {
        // SE reduce: 96 → 4 channels.
        let mut b = GraphBuilder::new("fc", Shape::vec(96));
        let x = b.input_id();
        let f = b.fc("f", x, 4);
        let _a = b.activation("a", f, Activation::Swish);
        let gg = analyze(&b.finish());
        let gi = gg.groups.iter().position(|g| g.kind == GroupKind::Fc).unwrap();
        let cycles = compute_cycles(&gg, &gg.groups[gi], &cfg());
        assert_eq!(cycles, 2); // ceil(96/64)·ceil(4/128) = 2·1
    }
}
