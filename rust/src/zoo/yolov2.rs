//! YOLOv2 (Darknet19 backbone + passthrough detector) — Fig 16 workload.

use crate::graph::{Activation, Graph, GraphBuilder, NodeId, PadMode, Shape};

/// YOLOv2 at the given input size (paper uses 416×416).
///
/// 23 convolution layers: Darknet19's 18 backbone convs + 2×3×3-1024,
/// the 64-channel passthrough conv, the post-concat 3×3-1024 and the
/// 1×1 detection conv. Leaky-ReLU activations, batch-norm everywhere
/// except the detection layer — mirroring the Darknet cfg the TF frozen
/// model is converted from.
pub fn yolov2(input: usize) -> Graph {
    let mut b = GraphBuilder::new("YOLOv2", Shape::new(input, input, 3));
    let mut idx = 0usize;
    let mut cba = |b: &mut GraphBuilder, from: NodeId, k: usize, c: usize| -> NodeId {
        idx += 1;
        b.conv_bn_act(&format!("conv{idx}"), from, k, 1, c, Activation::Leaky)
    };

    let x = b.input_id();
    let c1 = cba(&mut b, x, 3, 32);
    let p1 = b.maxpool("pool1", c1, 2, 2);
    let c2 = cba(&mut b, p1, 3, 64);
    let p2 = b.maxpool("pool2", c2, 2, 2);
    let c3 = cba(&mut b, p2, 3, 128);
    let c4 = cba(&mut b, c3, 1, 64);
    let c5 = cba(&mut b, c4, 3, 128);
    let p3 = b.maxpool("pool3", c5, 2, 2);
    let c6 = cba(&mut b, p3, 3, 256);
    let c7 = cba(&mut b, c6, 1, 128);
    let c8 = cba(&mut b, c7, 3, 256);
    let p4 = b.maxpool("pool4", c8, 2, 2);
    let c9 = cba(&mut b, p4, 3, 512);
    let c10 = cba(&mut b, c9, 1, 256);
    let c11 = cba(&mut b, c10, 3, 512);
    let c12 = cba(&mut b, c11, 1, 256);
    let c13 = cba(&mut b, c12, 3, 512); // passthrough source (26x26x512)
    let p5 = b.maxpool("pool5", c13, 2, 2);
    let c14 = cba(&mut b, p5, 3, 1024);
    let c15 = cba(&mut b, c14, 1, 512);
    let c16 = cba(&mut b, c15, 3, 1024);
    let c17 = cba(&mut b, c16, 1, 512);
    let c18 = cba(&mut b, c17, 3, 1024);
    let c19 = cba(&mut b, c18, 3, 1024);
    let c20 = cba(&mut b, c19, 3, 1024);
    // Passthrough branch: 1x1-64 on conv13, then space-to-depth
    // (26x26x64 -> 13x13x256). The reorg is pure data movement; we model
    // its geometry as four stride-2 window picks concatenated channel-wise,
    // which moves exactly the same 26·26·64 elements through the memory
    // system as the Darknet reorg layer.
    let c21 = cba(&mut b, c13, 1, 64); // 26x26x64
    let r1 = b.maxpool("reorg/s2a", c21, 2, 2); // 13x13x64 (quadrant a)
    let r2 = b.maxpool("reorg/s2b", c21, 2, 2); // 13x13x64 (quadrant b)
    let r3 = b.maxpool("reorg/s2c", c21, 2, 2);
    let r4 = b.maxpool("reorg/s2d", c21, 2, 2);
    let rc1 = b.concat("reorg/cat1", r1, r2); // 13x13x128
    let rc2 = b.concat("reorg/cat2", r3, r4); // 13x13x128
    let reorg = b.concat("reorg/cat3", rc1, rc2); // 13x13x256
    let cat = b.concat("route", reorg, c20); // 13x13x1280
    let c22 = cba(&mut b, cat, 3, 1024);
    idx += 1;
    let det = b.conv(&format!("conv{idx}"), c22, 1, 1, 425, PadMode::Same);
    b.identity("detect", det);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_count() {
        assert_eq!(yolov2(416).conv_layer_count(), 23);
    }

    #[test]
    fn gop_matches_darknet() {
        // Darknet reports ~29.4 BFLOPs for YOLOv2@416 ⇒ ~14.7 GMAC.
        // Paper Table V lists 17.18 GOP for their converted model at 416.
        let gop = yolov2(416).total_gop();
        assert!(gop > 25.0 && gop < 35.0, "got {gop}");
    }

    #[test]
    fn detect_is_13x13() {
        let g = yolov2(416);
        let out = g.outputs()[0];
        assert_eq!(g.node(out).out_shape, Shape::new(13, 13, 425));
    }

    #[test]
    fn weights_about_50mb() {
        // YOLOv2 has ~50.6M parameters.
        let mb = yolov2(416).total_weight_bytes(1) as f64 / 1e6;
        assert!((mb - 50.5).abs() < 2.0, "got {mb}");
    }
}
