//! VGG16 convolutional layers (the "VGG-CONV" workload of Table IV).

use crate::graph::{Activation, Graph, GraphBuilder, PadMode, Shape};

/// VGG16 CONV layers only (13 convolutions, 5 max-pools) — the workload
/// SmartShuttle and OLAccel report DRAM traffic for (Table IV). The three
/// FC layers are excluded, as in the paper's "VGG-CONV".
pub fn vgg16_conv(input: usize) -> Graph {
    let mut b = GraphBuilder::new("VGG16-CONV", Shape::new(input, input, 3));
    let mut x = b.input_id();
    let cfg: &[(usize, usize)] = &[(64, 2), (128, 2), (256, 3), (512, 3), (512, 3)];
    for (si, &(c, reps)) in cfg.iter().enumerate() {
        for r in 0..reps {
            let name = format!("conv{}_{}", si + 1, r + 1);
            let conv = b.conv(&name, x, 3, 1, c, PadMode::Same);
            let bias = b.bias(&format!("{name}/bias"), conv);
            x = b.activation(&format!("{name}/relu"), bias, Activation::Relu);
        }
        x = b.maxpool(&format!("pool{}", si + 1), x, 2, 2);
    }
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thirteen_convs() {
        let g = vgg16_conv(224);
        assert_eq!(g.conv_layer_count(), 13);
    }

    #[test]
    fn gop_matches_published() {
        // VGG16 CONV layers are ~30.7 GOP at 224x224 (15.3 GMAC).
        let gop = vgg16_conv(224).total_gop();
        assert!((gop - 30.7).abs() < 0.5, "got {gop}");
    }

    #[test]
    fn weights_match_published() {
        // VGG16 conv weights: 14.71 M parameters.
        let w = vgg16_conv(224).total_weight_bytes(1) as f64 / 1e6;
        assert!((w - 14.7).abs() < 0.2, "got {w} MB");
    }

    #[test]
    fn final_shape() {
        let g = vgg16_conv(224);
        let out = g.outputs()[0];
        assert_eq!(g.node(out).out_shape, Shape::new(7, 7, 512));
    }
}
