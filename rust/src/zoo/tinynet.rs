//! TinyNet-SE: the end-to-end hardware-verification network.
//!
//! A deliberately small CNN that exercises *every* datapath feature the
//! accelerator supports — normal conv, fused max-pool, residual shortcut
//! (both act-after-add and linear-add), MBConv with squeeze-and-
//! excitation (GAP, FC, swish/sigmoid LUTs, channel scale), stride-2
//! downsampling, nearest-neighbour upsampling and concatenation.
//!
//! `python/compile/model.py` defines the *same* network with the *same
//! node names*; the AOT pipeline exports its HLO + quantized parameters,
//! and `examples/e2e_verify.rs` checks the rust functional simulator
//! against the PJRT-executed golden model **bit-exactly**. Keep the two
//! definitions in lock-step.

use crate::graph::{Activation, Graph, GraphBuilder, PadMode, Shape};

/// Canonical input: 16×16×8.
pub const TINYNET_INPUT: Shape = Shape::new(16, 16, 8);

/// Build TinyNet-SE.
pub fn tinynet() -> Graph {
    let mut b = GraphBuilder::new("TinyNet-SE", TINYNET_INPUT);
    let x = b.input_id();

    // stem: conv3x3-16 + bias + relu, then 2x2 max-pool (fuses)
    let stem = b.conv("stem", x, 3, 1, 16, PadMode::Same);
    let stem_b = b.bias("stem/bias", stem);
    let stem_r = b.activation("stem/relu", stem_b, Activation::Relu);
    let pool = b.maxpool("pool1", stem_r, 2, 2); // 8x8x16

    // res1: classic residual block, ReLU after the addition
    let r1a = b.conv("res1/a", pool, 3, 1, 16, PadMode::Same);
    let r1a_b = b.bias("res1/a/bias", r1a);
    let r1a_r = b.activation("res1/a/relu", r1a_b, Activation::Relu);
    let r1b = b.conv("res1/b", r1a_r, 3, 1, 16, PadMode::Same);
    let r1b_b = b.bias("res1/b/bias", r1b);
    let r1_add = b.add("res1/add", r1b_b, pool);
    let r1 = b.activation("res1/relu", r1_add, Activation::Relu);

    // mb1: MBConv with SE (Fig. 1 / Fig. 13c-d), linear projection + add
    let exp = b.conv("mb1/expand", r1, 1, 1, 32, PadMode::Same);
    let exp_b = b.bias("mb1/expand/bias", exp);
    let exp_a = b.activation("mb1/expand/swish", exp_b, Activation::Swish);
    let dw = b.dwconv("mb1/dw", exp_a, 3, 1, PadMode::Same);
    let dw_b = b.bias("mb1/dw/bias", dw);
    let dw_a = b.activation("mb1/dw/swish", dw_b, Activation::Swish);
    let sq = b.gap("mb1/se/gap", dw_a);
    let se_r = b.fc("mb1/se/reduce", sq, 8);
    let se_ra = b.activation("mb1/se/reduce/swish", se_r, Activation::Swish);
    let se_e = b.fc("mb1/se/expand", se_ra, 32);
    let se_ea = b.activation("mb1/se/expand/sigmoid", se_e, Activation::Sigmoid);
    let se_s = b.scale("mb1/se/scale", dw_a, se_ea);
    let proj = b.conv("mb1/project", se_s, 1, 1, 16, PadMode::Same);
    let proj_b = b.bias("mb1/project/bias", proj);
    let mb1 = b.add("mb1/add", proj_b, r1); // linear add (no act)

    // multi-scale branch: stride-2 conv, upsample back, concat
    let down = b.conv("down", mb1, 3, 2, 24, PadMode::Same);
    let down_b = b.bias("down/bias", down);
    let down_r = b.activation("down/relu", down_b, Activation::Relu); // 4x4x24
    let up = b.upsample("up", down_r, 2); // 8x8x24
    let cat = b.concat("cat", mb1, up); // 8x8x40

    // head: 1x1 conv, GAP, classifier
    let head = b.conv("head", cat, 1, 1, 16, PadMode::Same);
    let head_b = b.bias("head/bias", head);
    let head_r = b.activation("head/relu", head_b, Activation::Relu);
    let g = b.gap("gap", head_r);
    let fc = b.fc("fc", g, 10);
    b.identity("logits", fc);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analyzer::{analyze, GroupKind};
    use crate::graph::validate;

    #[test]
    fn valid_and_small() {
        let g = tinynet();
        validate(&g).unwrap();
        assert!(g.nodes.len() < 40);
        // 6 normal convs + 1 dwconv + 2 SE FCs + head FC + fc = 11
        assert_eq!(g.conv_layer_count(), 11);
    }

    #[test]
    fn exercises_every_group_kind() {
        let gg = analyze(&tinynet());
        use GroupKind::*;
        for kind in [Conv, DwConv, Fc, Scale, Concat, Input] {
            assert!(
                gg.groups.iter().any(|g| g.kind == kind),
                "missing group kind {kind:?}"
            );
        }
        // the stem's max-pool fuses behind the conv (Algorithm 1 step 4)
        assert!(gg.groups.iter().any(|g| g.pool.is_some()));
        // both fused-shortcut flavours present
        let fused: Vec<_> = gg.groups.iter().filter(|g| g.shortcut_of.is_some()).collect();
        assert_eq!(fused.len(), 2);
        assert!(fused.iter().any(|g| g.act == Activation::Relu)); // res1
        assert!(fused.iter().any(|g| g.act == Activation::Linear)); // mb1
        // SE squeeze fused into the dw group
        assert!(gg.groups.iter().any(|g| g.se_squeeze && g.kind == DwConv));
        // upsample fused into `down`'s group
        assert!(gg.groups.iter().any(|g| g.upsample == Some(2)));
    }

    #[test]
    fn output_is_ten_logits() {
        let g = tinynet();
        let out = g.outputs()[0];
        assert_eq!(g.node(out).out_shape, Shape::vec(10));
    }
}
