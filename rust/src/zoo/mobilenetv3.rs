//! MobileNetV3-Large (Howard et al. 2019) — SE-based compact CNN from the
//! paper's motivation (Fig 1 mentions MobileNet v3 alongside EfficientNet).

use crate::graph::{Activation, Graph, GraphBuilder, NodeId, PadMode, Shape};

/// One bneck row of the MobileNetV3-Large table.
struct Bneck {
    k: usize,
    exp: usize,
    out: usize,
    se: bool,
    act: Activation,
    stride: usize,
}

fn large_plan() -> Vec<Bneck> {
    use Activation::{HardSwish as HS, Relu as RE};
    vec![
        Bneck { k: 3, exp: 16, out: 16, se: false, act: RE, stride: 1 },
        Bneck { k: 3, exp: 64, out: 24, se: false, act: RE, stride: 2 },
        Bneck { k: 3, exp: 72, out: 24, se: false, act: RE, stride: 1 },
        Bneck { k: 5, exp: 72, out: 40, se: true, act: RE, stride: 2 },
        Bneck { k: 5, exp: 120, out: 40, se: true, act: RE, stride: 1 },
        Bneck { k: 5, exp: 120, out: 40, se: true, act: RE, stride: 1 },
        Bneck { k: 3, exp: 240, out: 80, se: false, act: HS, stride: 2 },
        Bneck { k: 3, exp: 200, out: 80, se: false, act: HS, stride: 1 },
        Bneck { k: 3, exp: 184, out: 80, se: false, act: HS, stride: 1 },
        Bneck { k: 3, exp: 184, out: 80, se: false, act: HS, stride: 1 },
        Bneck { k: 3, exp: 480, out: 112, se: true, act: HS, stride: 1 },
        Bneck { k: 3, exp: 672, out: 112, se: true, act: HS, stride: 1 },
        Bneck { k: 5, exp: 672, out: 160, se: true, act: HS, stride: 2 },
        Bneck { k: 5, exp: 960, out: 160, se: true, act: HS, stride: 1 },
        Bneck { k: 5, exp: 960, out: 160, se: true, act: HS, stride: 1 },
    ]
}

fn bneck(b: &mut GraphBuilder, base: &str, x: NodeId, r: &Bneck) -> NodeId {
    let in_c = b.shape(x).c;
    let expanded = if r.exp != in_c {
        b.conv_bn_act(&format!("{base}/expand"), x, 1, 1, r.exp, r.act)
    } else {
        x
    };
    let dw = b.dw_bn_act(&format!("{base}/dw"), expanded, r.k, r.stride, r.act);

    let gated = if r.se {
        // MobileNetV3 SE: squeeze channels = expanded/4, hard-sigmoid gate.
        let sq = b.gap(&format!("{base}/se/gap"), dw);
        let f1 = b.fc(&format!("{base}/se/reduce"), sq, (r.exp / 4).max(1));
        let a1 = b.activation(&format!("{base}/se/relu"), f1, Activation::Relu);
        let f2 = b.fc(&format!("{base}/se/expand"), a1, r.exp);
        let a2 = b.activation(&format!("{base}/se/hsig"), f2, Activation::HardSigmoid);
        b.scale(&format!("{base}/se/scale"), dw, a2)
    } else {
        dw
    };

    let proj = b.conv(&format!("{base}/project"), gated, 1, 1, r.out, PadMode::Same);
    let proj_bn = b.batchnorm(&format!("{base}/project/bn"), proj);
    if r.stride == 1 && in_c == r.out {
        b.add(&format!("{base}/add"), proj_bn, x)
    } else {
        proj_bn
    }
}

/// MobileNetV3-Large classifier.
pub fn mobilenet_v3_large(input: usize) -> Graph {
    let mut b = GraphBuilder::new("MobileNetV3-Large", Shape::new(input, input, 3));
    let x = b.input_id();
    let mut x = b.conv_bn_act("stem", x, 3, 2, 16, Activation::HardSwish);
    for (i, r) in large_plan().iter().enumerate() {
        x = bneck(&mut b, &format!("bneck{}", i + 1), x, r);
    }
    let c_last = b.conv_bn_act("conv_last", x, 1, 1, 960, Activation::HardSwish);
    let g = b.gap("gap", c_last);
    let f1 = b.fc("fc1", g, 1280);
    let a1 = b.activation("fc1/hswish", f1, Activation::HardSwish);
    let fc = b.fc("fc1000", a1, 1000);
    b.identity("prob", fc);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifteen_bnecks() {
        let g = mobilenet_v3_large(224);
        let dws = g
            .nodes
            .iter()
            .filter(|n| matches!(n.op, crate::graph::OpKind::Conv { depthwise: true, .. }))
            .count();
        assert_eq!(dws, 15);
    }

    #[test]
    fn params_about_5_4m() {
        let m = mobilenet_v3_large(224).total_weight_bytes(1) as f64 / 1e6;
        assert!((m - 5.4).abs() < 0.6, "got {m}M");
    }

    #[test]
    fn gmacs_about_0_22() {
        // Published: 219 MMAC at 224x224 → 0.44 GOP.
        let gop = mobilenet_v3_large(224).total_gop();
        assert!((gop - 0.44).abs() < 0.1, "got {gop}");
    }
}
