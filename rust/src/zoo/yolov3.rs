//! YOLOv3 (Darknet53 backbone + FPN-style multi-scale heads) — Fig 17.

use crate::graph::{Activation, Graph, GraphBuilder, NodeId, PadMode, Shape};

/// Numbered conv+bn+leaky helper shared by the builder functions below.
fn cba(
    b: &mut GraphBuilder,
    idx: &mut usize,
    from: NodeId,
    k: usize,
    s: usize,
    c: usize,
) -> NodeId {
    *idx += 1;
    b.conv_bn_act(&format!("conv{idx}"), from, k, s, c, Activation::Leaky)
}

/// Darknet53 residual stage: stride-2 downsample conv + `n` residual blocks.
fn stage(
    b: &mut GraphBuilder,
    idx: &mut usize,
    res_idx: &mut usize,
    from: NodeId,
    c: usize,
    n: usize,
) -> NodeId {
    let mut x = cba(b, idx, from, 3, 2, c);
    for _ in 0..n {
        let c1 = cba(b, idx, x, 1, 1, c / 2);
        let c2 = cba(b, idx, c1, 3, 1, c);
        *res_idx += 1;
        x = b.add(&format!("res{res_idx}"), c2, x);
    }
    x
}

/// YOLO head: 5-conv block, then 3x3 + 1x1 detection conv.
/// Returns `(branch_point, detect_output)`.
fn head(
    b: &mut GraphBuilder,
    idx: &mut usize,
    from: NodeId,
    c: usize,
    tag: &str,
) -> (NodeId, NodeId) {
    let h1 = cba(b, idx, from, 1, 1, c);
    let h2 = cba(b, idx, h1, 3, 1, 2 * c);
    let h3 = cba(b, idx, h2, 1, 1, c);
    let h4 = cba(b, idx, h3, 3, 1, 2 * c);
    let h5 = cba(b, idx, h4, 1, 1, c); // branch point toward upsample
    let h6 = cba(b, idx, h5, 3, 1, 2 * c);
    *idx += 1;
    let det = b.conv(&format!("conv{idx}"), h6, 1, 1, 255, PadMode::Same);
    let out = b.identity(&format!("detect_{tag}"), det);
    (h5, out)
}

/// YOLOv3 at the given input size (paper uses 416×416; 75 conv layers,
/// 106 total layers counting shortcut/route/upsample, matching the
/// Darknet layer numbering referenced by Table III).
pub fn yolov3(input: usize) -> Graph {
    let mut b = GraphBuilder::new("YOLOv3", Shape::new(input, input, 3));
    let mut idx = 0usize;
    let mut res_idx = 0usize;

    let x0 = b.input_id();
    let c1 = cba(&mut b, &mut idx, x0, 3, 1, 32);
    let s1 = stage(&mut b, &mut idx, &mut res_idx, c1, 64, 1);
    let s2 = stage(&mut b, &mut idx, &mut res_idx, s1, 128, 2);
    let s3 = stage(&mut b, &mut idx, &mut res_idx, s2, 256, 8); // route 36 (52x52)
    let s4 = stage(&mut b, &mut idx, &mut res_idx, s3, 512, 8); // route 61 (26x26)
    let s5 = stage(&mut b, &mut idx, &mut res_idx, s4, 1024, 4); // 13x13

    let (h5a, _det1) = head(&mut b, &mut idx, s5, 512, "13");
    let u1c = cba(&mut b, &mut idx, h5a, 1, 1, 256);
    let u1 = b.upsample("upsample1", u1c, 2);
    let cat1 = b.concat("route1", u1, s4); // 26x26x768

    let (h5b, _det2) = head(&mut b, &mut idx, cat1, 256, "26");
    let u2c = cba(&mut b, &mut idx, h5b, 1, 1, 128);
    let u2 = b.upsample("upsample2", u2c, 2);
    let cat2 = b.concat("route2", u2, s3); // 52x52x384

    let (_h5c, _det3) = head(&mut b, &mut idx, cat2, 128, "52");
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_count_is_75() {
        assert_eq!(yolov3(416).conv_layer_count(), 75);
    }

    #[test]
    fn gop_matches_darknet() {
        // Darknet reports 65.86 BFLOPs for YOLOv3@416 — Table V's figure.
        let gop = yolov3(416).total_gop();
        assert!((gop - 65.86).abs() < 2.0, "got {gop}");
    }

    #[test]
    fn three_detection_scales() {
        let g = yolov3(416);
        let outs = g.outputs();
        assert_eq!(outs.len(), 3);
        let mut sizes: Vec<usize> = outs.iter().map(|&o| g.node(o).out_shape.h).collect();
        sizes.sort_unstable();
        assert_eq!(sizes, vec![13, 26, 52]);
    }

    #[test]
    fn weights_about_62m() {
        let m = yolov3(416).total_weight_bytes(1) as f64 / 1e6;
        assert!((m - 61.9).abs() < 2.0, "got {m}M");
    }

    #[test]
    fn residual_count() {
        let g = yolov3(416);
        let adds = g.nodes.iter().filter(|n| n.op.is_shortcut()).count();
        assert_eq!(adds, 1 + 2 + 8 + 8 + 4);
    }
}
