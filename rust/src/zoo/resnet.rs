//! ResNet family (He et al.) — Tables II/III/V/VI and Fig 17 workloads.

use crate::graph::{Activation, Graph, GraphBuilder, NodeId, PadMode, Shape};

/// Stage plan: (blocks per stage) for each depth.
fn plan(depth: usize) -> [usize; 4] {
    match depth {
        18 => [2, 2, 2, 2],
        34 => [3, 4, 6, 3],
        50 => [3, 4, 6, 3],
        101 => [3, 4, 23, 3],
        152 => [3, 8, 36, 3],
        _ => panic!("unsupported ResNet depth {depth}"),
    }
}

fn resnet(depth: usize, input: usize) -> Graph {
    let bottleneck = depth >= 50;
    let mut b = GraphBuilder::new(&format!("ResNet{depth}"), Shape::new(input, input, 3));
    let x = b.input_id();
    let c1 = b.conv_bn_act("conv1", x, 7, 2, 64, Activation::Relu);
    let mut x = b.maxpool("pool1", c1, 3, 2);

    let stage_c = [64usize, 128, 256, 512];
    for (si, &blocks) in plan(depth).iter().enumerate() {
        let c = stage_c[si];
        for bi in 0..blocks {
            let stride = if si > 0 && bi == 0 { 2 } else { 1 };
            let base = format!("res{}_{}", si + 2, bi + 1);
            x = if bottleneck {
                bottleneck_block(&mut b, &base, x, c, stride)
            } else {
                basic_block(&mut b, &base, x, c, stride)
            };
        }
    }
    let g = b.gap("gap", x);
    let fc = b.fc("fc1000", g, 1000);
    b.identity("prob", fc);
    b.finish()
}

/// 1x1 → 3x3 → 1x1(4c) bottleneck with projection shortcut on stage entry.
fn bottleneck_block(
    b: &mut GraphBuilder,
    base: &str,
    x: NodeId,
    c: usize,
    stride: usize,
) -> NodeId {
    let in_c = b.shape(x).c;
    let out_c = 4 * c;
    let c1 = b.conv_bn_act(&format!("{base}/a"), x, 1, 1, c, Activation::Relu);
    let c2 = b.conv_bn_act(&format!("{base}/b"), c1, 3, stride, c, Activation::Relu);
    let c3 = b.conv(&format!("{base}/c"), c2, 1, 1, out_c, PadMode::Same);
    let bn3 = b.batchnorm(&format!("{base}/c/bn"), c3);
    let shortcut = if in_c != out_c || stride != 1 {
        let p = b.conv(&format!("{base}/proj"), x, 1, stride, out_c, PadMode::Same);
        b.batchnorm(&format!("{base}/proj/bn"), p)
    } else {
        x
    };
    let add = b.add(&format!("{base}/add"), bn3, shortcut);
    b.activation(&format!("{base}/relu"), add, Activation::Relu)
}

/// 3x3 → 3x3 basic block (ResNet-18/34).
fn basic_block(b: &mut GraphBuilder, base: &str, x: NodeId, c: usize, stride: usize) -> NodeId {
    let in_c = b.shape(x).c;
    let c1 = b.conv_bn_act(&format!("{base}/a"), x, 3, stride, c, Activation::Relu);
    let c2 = b.conv(&format!("{base}/b"), c1, 3, 1, c, PadMode::Same);
    let bn2 = b.batchnorm(&format!("{base}/b/bn"), c2);
    let shortcut = if in_c != c || stride != 1 {
        let p = b.conv(&format!("{base}/proj"), x, 1, stride, c, PadMode::Same);
        b.batchnorm(&format!("{base}/proj/bn"), p)
    } else {
        x
    };
    let add = b.add(&format!("{base}/add"), bn2, shortcut);
    b.activation(&format!("{base}/relu"), add, Activation::Relu)
}

/// ResNet-18 (basic blocks) at a square input size.
pub fn resnet18(input: usize) -> Graph {
    resnet(18, input)
}
/// ResNet-34 (basic blocks) at a square input size.
pub fn resnet34(input: usize) -> Graph {
    resnet(34, input)
}
/// ResNet-50 (bottleneck blocks) at a square input size.
pub fn resnet50(input: usize) -> Graph {
    resnet(50, input)
}
/// ResNet-101 (bottleneck blocks) at a square input size.
pub fn resnet101(input: usize) -> Graph {
    resnet(101, input)
}
/// ResNet-152 (bottleneck blocks, Table II workload) at a square input
/// size.
pub fn resnet152(input: usize) -> Graph {
    resnet(152, input)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_counts() {
        // 53 weighted conv layers in ResNet50 (incl. projections) + FC.
        assert_eq!(resnet50(224).conv_layer_count(), 54);
        // ResNet152: 1 + (3+8+36+3)*3 + 4 proj = 155 convs + FC.
        assert_eq!(resnet152(224).conv_layer_count(), 156);
    }

    #[test]
    fn resnet50_gop_at_224() {
        // Published: ~3.86 GMAC = 7.7 GOP at 224; Table V lists 11.76 GOP
        // at 256 (scaling ~ (256/224)^2 = 1.306 → 10.1; theirs includes
        // extra head ops). Accept the canonical 224 figure.
        let gop = resnet50(224).total_gop();
        assert!((gop - 7.7).abs() < 0.7, "got {gop}");
    }

    #[test]
    fn resnet152_gop_scales() {
        let gop224 = resnet152(224).total_gop();
        // Published ResNet152: ~11.3 GMAC = 22.6 GOP (Table II: 22.63 GOP).
        assert!((gop224 - 22.6).abs() < 1.5, "got {gop224}");
        let gop256 = resnet152(256).total_gop();
        assert!(gop256 > gop224 * 1.2 && gop256 < gop224 * 1.45);
    }

    #[test]
    fn resnet152_weights_match_table2() {
        // Table II: 112.6 MB at 16-bit ⇒ ~56.3 M params ⇒ ~60.2 M with FC.
        let params = resnet152(224).total_weight_bytes(1) as f64 / 1e6;
        assert!((params - 60.2).abs() < 2.0, "got {params}M");
    }

    #[test]
    fn shortcut_fraction_is_large() {
        // [8]: shortcut data ≈ 40% of feature-map accesses in ResNet152.
        // Sanity: at least a third of blocks' outputs feed EltwiseAdd.
        let g = resnet152(224);
        let adds = g.nodes.iter().filter(|n| n.op.is_shortcut()).count();
        assert_eq!(adds, 3 + 8 + 36 + 3);
    }
}
