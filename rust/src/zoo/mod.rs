//! Model zoo: in-repo builders for every CNN the paper evaluates.
//!
//! The paper's front-end parses TensorFlow frozen protobufs; the graphs
//! below reproduce the *architectures* those protobufs describe (layer
//! geometry, shortcut/concat topology, SE blocks) at TF-node granularity,
//! which is everything the compiler/optimizer observes. See DESIGN.md §2
//! for the substitution rationale.
//!
//! | builder | paper usage |
//! |---|---|
//! | [`vgg16_conv`] | Table IV (vs OLAccel / SmartShuttle), Table III |
//! | [`yolov2`] | Fig 16, Table III, Table V |
//! | [`yolov3`] | Fig 17, Table III, Table V |
//! | [`resnet50`] / [`resnet152`] | Tables II/III/V/VI, Fig 17 |
//! | [`retinanet`] | Tables III/V |
//! | [`efficientnet_b1`] | Fig 17, Tables III/V/VII, Fig 18 |
//! | [`mobilenet_v3_large`] | §I motivation (SE-based compact CNN) |
//! | [`efficientdet_d0`] | multi-cut-point extension (Fig 12c) |

mod vgg;
mod yolov2;
mod yolov3;
mod resnet;
mod retinanet;
mod efficientnet;
mod mobilenetv3;
mod efficientdet;
mod tinynet;
mod unet;

pub use vgg::vgg16_conv;
pub use yolov2::yolov2;
pub use yolov3::yolov3;
pub use resnet::{resnet101, resnet152, resnet18, resnet34, resnet50};
pub use retinanet::retinanet;
pub use efficientnet::{efficientnet_b0, efficientnet_b1};
pub use mobilenetv3::mobilenet_v3_large;
pub use efficientdet::efficientdet_d0;
pub use tinynet::{tinynet, TINYNET_INPUT};
pub use unet::unet;

use crate::graph::Graph;

/// All zoo model names, for CLI listings and sweep drivers.
pub const MODEL_NAMES: &[&str] = &[
    "vgg16-conv",
    "yolov2",
    "yolov3",
    "resnet18",
    "resnet34",
    "resnet50",
    "resnet101",
    "resnet152",
    "retinanet",
    "efficientnet-b0",
    "efficientnet-b1",
    "mobilenetv3-large",
    "efficientdet-d0",
    "unet",
];

/// Build a zoo model by name at the given square input size.
pub fn by_name(name: &str, input: usize) -> Option<Graph> {
    Some(match name {
        "vgg16-conv" => vgg16_conv(input),
        "yolov2" => yolov2(input),
        "yolov3" => yolov3(input),
        "resnet18" => resnet18(input),
        "resnet34" => resnet34(input),
        "resnet50" => resnet50(input),
        "resnet101" => resnet101(input),
        "resnet152" => resnet152(input),
        "retinanet" => retinanet(input),
        "efficientnet-b0" => efficientnet_b0(input),
        "efficientnet-b1" => efficientnet_b1(input),
        "mobilenetv3-large" => mobilenet_v3_large(input),
        "efficientdet-d0" => efficientdet_d0(input),
        "unet" => unet(input),
        _ => return None,
    })
}

/// Default input size used by the paper for each model (Tables III/V).
pub fn default_input(name: &str) -> usize {
    match name {
        "vgg16-conv" | "resnet18" | "resnet34" => 224,
        "resnet50" | "resnet101" | "resnet152" => 256,
        "yolov2" | "yolov3" => 416,
        "retinanet" | "efficientdet-d0" => 512,
        "unet" => 256,
        _ => 256,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::validate;

    #[test]
    fn all_models_build_and_validate() {
        for &name in MODEL_NAMES {
            let g = by_name(name, default_input(name)).unwrap();
            validate(&g).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(g.conv_layer_count() > 5, "{name} too small");
        }
    }

    #[test]
    fn unknown_model_is_none() {
        assert!(by_name("alexnet", 224).is_none());
    }
}
