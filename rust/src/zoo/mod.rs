//! Model zoo: in-repo builders for every CNN the paper evaluates.
//!
//! The paper's front-end parses TensorFlow frozen protobufs; the graphs
//! below reproduce the *architectures* those protobufs describe (layer
//! geometry, shortcut/concat topology, SE blocks) at TF-node granularity,
//! which is everything the compiler/optimizer observes. See DESIGN.md §2
//! for the substitution rationale.
//!
//! | builder | paper usage |
//! |---|---|
//! | [`vgg16_conv`] | Table IV (vs OLAccel / SmartShuttle), Table III |
//! | [`yolov2`] | Fig 16, Table III, Table V |
//! | [`yolov3`] | Fig 17, Table III, Table V |
//! | [`resnet50`] / [`resnet152`] | Tables II/III/V/VI, Fig 17 |
//! | [`retinanet`] | Tables III/V |
//! | [`efficientnet_b1`] | Fig 17, Tables III/V/VII, Fig 18 |
//! | [`mobilenet_v3_large`] | §I motivation (SE-based compact CNN) |
//! | [`efficientdet_d0`] | multi-cut-point extension (Fig 12c) |

mod vgg;
mod yolov2;
mod yolov3;
mod resnet;
mod retinanet;
mod efficientnet;
mod mobilenetv3;
mod efficientdet;
mod tinynet;
mod unet;

pub use vgg::vgg16_conv;
pub use yolov2::yolov2;
pub use yolov3::yolov3;
pub use resnet::{resnet101, resnet152, resnet18, resnet34, resnet50};
pub use retinanet::retinanet;
pub use efficientnet::{efficientnet_b0, efficientnet_b1};
pub use mobilenetv3::mobilenet_v3_large;
pub use efficientdet::efficientdet_d0;
pub use tinynet::{tinynet, TINYNET_INPUT};
pub use unet::unet;

use crate::graph::Graph;

/// The paper-evaluation zoo: every model the tables/figures sweep, for
/// CLI listings and sweep drivers. `tinynet` (the hardware-verification
/// net) resolves through [`by_name`] but is deliberately excluded here so
/// zoo-wide sweeps stay paper-shaped; [`KNOWN_NAMES`] is the superset.
pub const MODEL_NAMES: &[&str] = &[
    "vgg16-conv",
    "yolov2",
    "yolov3",
    "resnet18",
    "resnet34",
    "resnet50",
    "resnet101",
    "resnet152",
    "retinanet",
    "efficientnet-b0",
    "efficientnet-b1",
    "mobilenetv3-large",
    "efficientdet-d0",
    "unet",
];

/// `tinynet` is fixed-geometry (its canonical 16×16×8 input is part of
/// the golden-model contract) and ignores the requested input size.
fn build_tinynet(_input: usize) -> Graph {
    tinynet()
}

/// One table drives the whole registry — names, builders, paper default
/// inputs and the fixed-geometry property cannot drift apart
/// ([`KNOWN_NAMES`], [`by_name`], [`try_default_input`] and
/// [`fixed_input`] all expand from the same rows; the input column is
/// either `any N` (rebuilds at any resolution, paper default `N`) or
/// `fixed N` (only buildable at `N`)).
macro_rules! zoo_registry {
    ($( $name:literal => ($builder:expr, $kind:ident $default:expr) ),+ $(,)?) => {
        /// Every name [`by_name`] accepts: [`MODEL_NAMES`] plus
        /// `tinynet`. This is what
        /// [`crate::compiler::CompileError::unknown_model`] reports.
        pub const KNOWN_NAMES: &[&str] = &[$($name),+];

        /// Build a zoo model by name at the given square input size.
        ///
        /// `tinynet` is fixed-geometry (its canonical 16×16×8 input is
        /// part of the golden-model contract) and ignores `input` —
        /// callers taking user-chosen sizes guard with [`fixed_input`].
        pub fn by_name(name: &str, input: usize) -> Option<Graph> {
            let build: fn(usize) -> Graph = match name {
                $( $name => $builder, )+
                _ => return None,
            };
            Some(build(input))
        }

        /// Default input size used by the paper for each model
        /// (Tables III/V), or `None` for names outside the zoo.
        pub fn try_default_input(name: &str) -> Option<usize> {
            Some(match name {
                $( $name => $default, )+
                _ => return None,
            })
        }

        /// The mandatory input size of a fixed-geometry model, or
        /// `None` for models that rebuild at any resolution. Callers
        /// that accept a user-chosen input (CLI flags, sweep axes) use
        /// this to reject or normalize sizes the builder would silently
        /// ignore.
        pub fn fixed_input(name: &str) -> Option<usize> {
            match name {
                $( $name => zoo_registry!(@fixed $kind $default), )+
                _ => None,
            }
        }
    };
    (@fixed any $default:expr) => { None };
    (@fixed fixed $default:expr) => { Some($default) };
}

zoo_registry! {
    "vgg16-conv" => (vgg16_conv, any 224),
    "yolov2" => (yolov2, any 416),
    "yolov3" => (yolov3, any 416),
    "resnet18" => (resnet18, any 224),
    "resnet34" => (resnet34, any 224),
    "resnet50" => (resnet50, any 256),
    "resnet101" => (resnet101, any 256),
    "resnet152" => (resnet152, any 256),
    "retinanet" => (retinanet, any 512),
    "efficientnet-b0" => (efficientnet_b0, any 256),
    "efficientnet-b1" => (efficientnet_b1, any 256),
    "mobilenetv3-large" => (mobilenet_v3_large, any 256),
    "efficientdet-d0" => (efficientdet_d0, any 512),
    "unet" => (unet, any 256),
    "tinynet" => (build_tinynet, fixed TINYNET_INPUT.w),
}

/// Default input size used by the paper for each model (Tables III/V).
///
/// Falls back to 256 for unknown names; callers that must reject unknown
/// models use [`try_default_input`] (sweep construction goes through
/// `SweepJob::zoo_default`, which surfaces a typed error instead).
pub fn default_input(name: &str) -> usize {
    try_default_input(name).unwrap_or(256)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::validate;

    #[test]
    fn all_models_build_and_validate() {
        for &name in MODEL_NAMES {
            let g = by_name(name, default_input(name)).unwrap();
            validate(&g).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(g.conv_layer_count() > 5, "{name} too small");
        }
    }

    #[test]
    fn unknown_model_is_none() {
        assert!(by_name("alexnet", 224).is_none());
        assert!(try_default_input("alexnet").is_none());
    }

    #[test]
    fn known_names_covers_the_registry() {
        // KNOWN_NAMES is exactly what by_name resolves: the sweep zoo
        // plus the fixed-geometry verification net.
        for &name in KNOWN_NAMES {
            assert!(by_name(name, default_input(name)).is_some(), "{name}");
            assert!(try_default_input(name).is_some(), "{name}");
        }
        // every sweep-zoo model must stay resolvable (a MODEL_NAMES entry
        // missing from KNOWN_NAMES would break SweepJob::zoo_default)
        for &name in MODEL_NAMES {
            assert!(KNOWN_NAMES.contains(&name), "{name} missing from KNOWN_NAMES");
        }
        let unique: std::collections::BTreeSet<_> = KNOWN_NAMES.iter().collect();
        assert_eq!(unique.len(), KNOWN_NAMES.len(), "duplicate KNOWN_NAMES entry");
        assert_eq!(KNOWN_NAMES.len(), MODEL_NAMES.len() + 1);
        assert!(KNOWN_NAMES.contains(&"tinynet"));
        assert!(!MODEL_NAMES.contains(&"tinynet"));
        assert_eq!(default_input("tinynet"), TINYNET_INPUT.w);
        assert_eq!(fixed_input("tinynet"), Some(TINYNET_INPUT.w));
        assert_eq!(fixed_input("resnet18"), None);
    }
}
