//! EfficientDet-D0 (EfficientNet-B0 backbone + BiFPN) — exercises the
//! multi-cut-point rule of §IV: cut-points = 2 × repeated BiFPN blocks + 1
//! (Fig 12c).

use crate::graph::{Activation, Graph, GraphBuilder, NodeId, PadMode, Shape};

/// Depthwise-separable conv (EfficientDet's BiFPN/head conv flavour).
fn sepconv(b: &mut GraphBuilder, base: &str, x: NodeId, out_c: usize) -> NodeId {
    let dw = b.dw_bn_act(&format!("{base}/dw"), x, 3, 1, Activation::Swish);
    let pw = b.conv(&format!("{base}/pw"), dw, 1, 1, out_c, PadMode::Same);
    b.batchnorm(&format!("{base}/pw/bn"), pw)
}

/// EfficientNet-B0 backbone tapped at P3/P4/P5 (stride 8/16/32).
fn backbone(b: &mut GraphBuilder, inp: NodeId) -> (NodeId, NodeId, NodeId) {
    // Condensed B0 trunk: geometry-faithful MBConv stages with SE,
    // re-using the stage plan of `efficientnet.rs` but tapping stride
    // milestones. (Kept separate to avoid cross-module private APIs.)
    let stem = b.conv_bn_act("stem", inp, 3, 2, 32, Activation::Swish);
    let mut x = stem;
    let mut taps: Vec<NodeId> = Vec::new();
    let plan: [(usize, usize, usize, usize, usize); 7] = [
        // expand, out_c, repeats, stride, k
        (1, 16, 1, 1, 3),
        (6, 24, 2, 2, 3),
        (6, 40, 2, 2, 5),
        (6, 80, 3, 2, 3),
        (6, 112, 3, 1, 5),
        (6, 192, 4, 2, 5),
        (6, 320, 1, 1, 3),
    ];
    for (si, &(e, c, r, s, k)) in plan.iter().enumerate() {
        for bi in 0..r {
            let stride = if bi == 0 { s } else { 1 };
            x = mbconv(b, &format!("block{}_{}", si + 1, bi + 1), x, e, c, k, stride);
        }
        // P3 after stage 3 (stride 8), P4 after stage 5 (stride 16),
        // P5 after stage 7 (stride 32).
        if si == 2 || si == 4 || si == 6 {
            taps.push(x);
        }
    }
    (taps[0], taps[1], taps[2])
}

fn mbconv(
    b: &mut GraphBuilder,
    base: &str,
    x: NodeId,
    expand: usize,
    out_c: usize,
    k: usize,
    stride: usize,
) -> NodeId {
    let in_c = b.shape(x).c;
    let exp_c = in_c * expand;
    let se_c = (in_c / 4).max(1);
    let expanded = if expand != 1 {
        b.conv_bn_act(&format!("{base}/expand"), x, 1, 1, exp_c, Activation::Swish)
    } else {
        x
    };
    let dw = b.dw_bn_act(&format!("{base}/dw"), expanded, k, stride, Activation::Swish);
    let sq = b.gap(&format!("{base}/se/gap"), dw);
    let f1 = b.fc(&format!("{base}/se/reduce"), sq, se_c);
    let a1 = b.activation(&format!("{base}/se/swish"), f1, Activation::Swish);
    let f2 = b.fc(&format!("{base}/se/expand"), a1, exp_c);
    let a2 = b.activation(&format!("{base}/se/sig"), f2, Activation::Sigmoid);
    let sc = b.scale(&format!("{base}/se/scale"), dw, a2);
    let pj = b.conv(&format!("{base}/project"), sc, 1, 1, out_c, PadMode::Same);
    let pb = b.batchnorm(&format!("{base}/project/bn"), pj);
    if stride == 1 && in_c == out_c {
        b.add(&format!("{base}/add"), pb, x)
    } else {
        pb
    }
}

/// One BiFPN layer over levels P3..P7 (64 channels for D0).
/// Feature fusion is modelled as eltwise-add merges (fast-normalized
/// fusion is an element-wise weighted sum — identical memory behaviour).
fn bifpn_layer(b: &mut GraphBuilder, tag: &str, p: [NodeId; 5]) -> [NodeId; 5] {
    let c = 64usize;
    let [p3, p4, p5, p6, p7] = p;

    // Top-down path
    let p7u = b.upsample(&format!("{tag}/p7_up"), p7, 2);
    let p6m = b.add(&format!("{tag}/p6_td_add"), p6, p7u);
    let p6td = sepconv(b, &format!("{tag}/p6_td"), p6m, c);
    let p6u = b.upsample(&format!("{tag}/p6_up"), p6td, 2);
    let p5m = b.add(&format!("{tag}/p5_td_add"), p5, p6u);
    let p5td = sepconv(b, &format!("{tag}/p5_td"), p5m, c);
    let p5u = b.upsample(&format!("{tag}/p5_up"), p5td, 2);
    let p4m = b.add(&format!("{tag}/p4_td_add"), p4, p5u);
    let p4td = sepconv(b, &format!("{tag}/p4_td"), p4m, c);
    let p4u = b.upsample(&format!("{tag}/p4_up"), p4td, 2);
    let p3m = b.add(&format!("{tag}/p3_add"), p3, p4u);
    let p3o = sepconv(b, &format!("{tag}/p3_out"), p3m, c);

    // Bottom-up path
    let p3d = b.maxpool(&format!("{tag}/p3_down"), p3o, 3, 2);
    let p4m2 = b.add(&format!("{tag}/p4_bu_add"), p4td, p3d);
    let p4o = sepconv(b, &format!("{tag}/p4_out"), p4m2, c);
    let p4d = b.maxpool(&format!("{tag}/p4_down"), p4o, 3, 2);
    let p5m2 = b.add(&format!("{tag}/p5_bu_add"), p5td, p4d);
    let p5o = sepconv(b, &format!("{tag}/p5_out"), p5m2, c);
    let p5d = b.maxpool(&format!("{tag}/p5_down"), p5o, 3, 2);
    let p6m2 = b.add(&format!("{tag}/p6_bu_add"), p6td, p5d);
    let p6o = sepconv(b, &format!("{tag}/p6_out"), p6m2, c);
    let p6d = b.maxpool(&format!("{tag}/p6_down"), p6o, 3, 2);
    let p7m2 = b.add(&format!("{tag}/p7_bu_add"), p7, p6d);
    let p7o = sepconv(b, &format!("{tag}/p7_out"), p7m2, c);

    [p3o, p4o, p5o, p6o, p7o]
}

/// EfficientDet-D0 at the given input size (512 canonical), with
/// `repeats` BiFPN layers (3 for D0).
pub fn efficientdet_d0(input: usize) -> Graph {
    let repeats = 3;
    let c = 64usize;
    let mut b = GraphBuilder::new("EfficientDet-D0", Shape::new(input, input, 3));
    let inp = b.input_id();
    let (c3, c4, c5) = backbone(&mut b, inp);

    // Resample backbone taps into the BiFPN width.
    let p3 = b.conv("bifpn_in/p3", c3, 1, 1, c, PadMode::Same);
    let p4 = b.conv("bifpn_in/p4", c4, 1, 1, c, PadMode::Same);
    let p5 = b.conv("bifpn_in/p5", c5, 1, 1, c, PadMode::Same);
    let p6 = b.conv("bifpn_in/p6", c5, 3, 2, c, PadMode::Same);
    let p7 = b.maxpool("bifpn_in/p7", p6, 3, 2);

    let mut levels = [p3, p4, p5, p6, p7];
    for r in 0..repeats {
        levels = bifpn_layer(&mut b, &format!("bifpn{}", r + 1), levels);
    }

    // Class/box heads (3 sepconv layers for D0) per level.
    for (li, &p) in levels.iter().enumerate() {
        let tag = format!("head_p{}", li + 3);
        let mut x = p;
        for i in 0..3 {
            x = sepconv(&mut b, &format!("{tag}/cls{i}"), x, c);
        }
        let cls = b.conv(&format!("{tag}/cls_pred"), x, 3, 1, 9 * 90, PadMode::Same);
        b.identity(&format!("{tag}/cls_out"), cls);
        let mut y = p;
        for i in 0..3 {
            y = sepconv(&mut b, &format!("{tag}/box{i}"), y, c);
        }
        let bx = b.conv(&format!("{tag}/box_pred"), y, 3, 1, 9 * 4, PadMode::Same);
        b.identity(&format!("{tag}/box_out"), bx);
    }
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_and_has_bifpn_adds() {
        let g = efficientdet_d0(512);
        let adds = g
            .nodes
            .iter()
            .filter(|n| n.op.is_shortcut() && n.name.contains("bifpn"))
            .count();
        // 8 fusion adds per BiFPN layer × 3 layers.
        assert_eq!(adds, 24);
    }

    #[test]
    fn ten_head_outputs() {
        assert_eq!(efficientdet_d0(512).outputs().len(), 10);
    }

    #[test]
    fn gop_small() {
        // EfficientDet-D0: ~2.5 BFLOPs per the paper's Fig 12 family.
        let gop = efficientdet_d0(512).total_gop();
        assert!(gop > 1.0 && gop < 12.0, "got {gop}");
    }
}
