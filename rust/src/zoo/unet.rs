//! U-Net style auto-encoder — the paper's *double cut-point* example
//! (Fig. 11 right: "an auto-encoder CNN has two cut-points": feature
//! maps shrink along the encoder, then grow along the decoder).

use crate::graph::{Activation, Graph, GraphBuilder, NodeId, PadMode, Shape};

fn enc_block(b: &mut GraphBuilder, base: &str, x: NodeId, c: usize) -> (NodeId, NodeId) {
    let c1 = b.conv_bn_act(&format!("{base}/conv1"), x, 3, 1, c, Activation::Relu);
    let c2 = b.conv_bn_act(&format!("{base}/conv2"), c1, 3, 1, c, Activation::Relu);
    let p = b.maxpool(&format!("{base}/pool"), c2, 2, 2);
    (c2, p) // (skip tap, downsampled)
}

fn dec_block(b: &mut GraphBuilder, base: &str, x: NodeId, skip: NodeId, c: usize) -> NodeId {
    let up = b.upsample(&format!("{base}/up"), x, 2);
    let uc = b.conv_bn_act(&format!("{base}/upconv"), up, 3, 1, c, Activation::Relu);
    let cat = b.concat(&format!("{base}/cat"), uc, skip);
    let c1 = b.conv_bn_act(&format!("{base}/conv1"), cat, 3, 1, c, Activation::Relu);
    b.conv_bn_act(&format!("{base}/conv2"), c1, 3, 1, c, Activation::Relu)
}

/// 4-level U-Net segmenter (skip connections via concat — the long-path
/// data the allocator keeps off-chip per §IV-A).
pub fn unet(input: usize) -> Graph {
    let mut b = GraphBuilder::new("U-Net", Shape::new(input, input, 3));
    let x = b.input_id();
    let (s1, p1) = enc_block(&mut b, "enc1", x, 32);
    let (s2, p2) = enc_block(&mut b, "enc2", p1, 64);
    let (s3, p3) = enc_block(&mut b, "enc3", p2, 128);
    let (s4, p4) = enc_block(&mut b, "enc4", p3, 256);

    let m1 = b.conv_bn_act("mid/conv1", p4, 3, 1, 512, Activation::Relu);
    let mid = b.conv_bn_act("mid/conv2", m1, 3, 1, 512, Activation::Relu);

    let d4 = dec_block(&mut b, "dec4", mid, s4, 256);
    let d3 = dec_block(&mut b, "dec3", d4, s3, 128);
    let d2 = dec_block(&mut b, "dec2", d3, s2, 64);
    let d1 = dec_block(&mut b, "dec1", d2, s1, 32);

    let seg = b.conv("head", d1, 1, 1, 2, PadMode::Same);
    b.identity("mask", seg);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analyzer::analyze;
    use crate::config::AccelConfig;
    use crate::optimizer::{basic_blocks, segments, Direction, Optimizer};

    #[test]
    fn builds_and_validates() {
        let g = unet(256);
        crate::graph::validate(&g).unwrap();
        assert_eq!(g.conv_layer_count(), 23);
        // output at full resolution
        let out = g.outputs()[0];
        assert_eq!(g.node(out).out_shape, Shape::new(256, 256, 2));
    }

    #[test]
    fn autoencoder_has_two_cut_points() {
        // Fig 11 (right): encoder (Dec) + decoder (Inc) = 2 segments.
        let gg = analyze(&unet(256));
        let blocks = basic_blocks(&gg);
        let segs = segments(&gg, &blocks);
        assert_eq!(segs.len(), 2, "{segs:?}");
        assert_eq!(segs[0].dir, Direction::Dec);
        assert_eq!(segs[1].dir, Direction::Inc);
    }

    #[test]
    fn optimizer_puts_frame_reuse_in_the_valley() {
        // frame-reuse belongs to the small-fmap middle; both ends of the
        // hourglass stream row-wise.
        let gg = analyze(&unet(256));
        let cfg = AccelConfig::kcu1500_int8();
        let opt = Optimizer::new(&gg, &cfg);
        let best = opt.optimize();
        assert!(best.feasible);
        use crate::isa::ReuseMode;
        let first_conv = 1; // enc1/conv1 group
        let mid = gg.groups.iter().position(|gr| {
            gg.graph.node(gr.main).name.starts_with("mid/")
        }).unwrap();
        assert_eq!(best.policy[first_conv], ReuseMode::Row, "encoder entry must stream");
        assert_eq!(best.policy[mid], ReuseMode::Frame, "bottleneck must stay on-chip");
    }
}
