//! EfficientNet-B0/B1 (MBConv + Squeeze-and-Excitation) — the paper's
//! headline compact-CNN workload (Fig 17, Tables III/V/VII, Fig 18).

use crate::graph::{Activation, Graph, GraphBuilder, NodeId, Shape};

/// One stage of the EfficientNet block plan.
struct Stage {
    expand: usize,
    out_c: usize,
    repeats: usize,
    stride: usize,
    k: usize,
}

/// B0 baseline plan (Tan & Le 2019, Table 1).
fn b0_plan() -> Vec<Stage> {
    vec![
        Stage { expand: 1, out_c: 16, repeats: 1, stride: 1, k: 3 },
        Stage { expand: 6, out_c: 24, repeats: 2, stride: 2, k: 3 },
        Stage { expand: 6, out_c: 40, repeats: 2, stride: 2, k: 5 },
        Stage { expand: 6, out_c: 80, repeats: 3, stride: 2, k: 3 },
        Stage { expand: 6, out_c: 112, repeats: 3, stride: 1, k: 5 },
        Stage { expand: 6, out_c: 192, repeats: 4, stride: 2, k: 5 },
        Stage { expand: 6, out_c: 320, repeats: 1, stride: 1, k: 3 },
    ]
}

/// Depth scaling: ceil(repeats × depth_mult), per the compound-scaling rule.
fn scale_depth(r: usize, depth_mult: f64) -> usize {
    (r as f64 * depth_mult).ceil() as usize
}

/// MBConv block with SE: expand 1×1 (skip when ratio 1) → depthwise k×k →
/// SE (squeeze → FC/4 → swish → FC → sigmoid → scale) → project 1×1,
/// with an identity shortcut when stride == 1 and channels match.
///
/// Node granularity mirrors the TF frozen graph (conv / bn / act / gap /
/// fc / scale / add all separate nodes) so the analyzer's grouping is
/// exercised exactly as in Fig. 5(a).
fn mbconv(b: &mut GraphBuilder, base: &str, x: NodeId, st: &Stage, stride: usize) -> NodeId {
    let in_c = b.shape(x).c;
    let exp_c = in_c * st.expand;
    // SE squeeze channels derive from the *block input* channels (ratio 0.25).
    let se_c = (in_c / 4).max(1);

    let expanded = if st.expand != 1 {
        b.conv_bn_act(&format!("{base}/expand"), x, 1, 1, exp_c, Activation::Swish)
    } else {
        x
    };
    let dw = b.dw_bn_act(&format!("{base}/dw"), expanded, st.k, stride, Activation::Swish);

    // Squeeze-and-Excitation (Fig 1 / Fig 13c-d of the paper).
    let sq = b.gap(&format!("{base}/se/gap"), dw);
    let r1 = b.fc(&format!("{base}/se/reduce"), sq, se_c);
    let a1 = b.activation(&format!("{base}/se/swish"), r1, Activation::Swish);
    let r2 = b.fc(&format!("{base}/se/expand"), a1, exp_c);
    let a2 = b.activation(&format!("{base}/se/sigmoid"), r2, Activation::Sigmoid);
    let scaled = b.scale(&format!("{base}/se/scale"), dw, a2);

    let proj =
        b.conv(&format!("{base}/project"), scaled, 1, 1, st.out_c, crate::graph::PadMode::Same);
    let proj_bn = b.batchnorm(&format!("{base}/project/bn"), proj);

    if stride == 1 && in_c == st.out_c {
        b.add(&format!("{base}/add"), proj_bn, x)
    } else {
        proj_bn
    }
}

fn efficientnet(name: &str, input: usize, depth_mult: f64) -> Graph {
    let mut b = GraphBuilder::new(name, Shape::new(input, input, 3));
    let x = b.input_id();
    let mut x = b.conv_bn_act("stem", x, 3, 2, 32, Activation::Swish);

    for (si, st) in b0_plan().iter().enumerate() {
        let reps = scale_depth(st.repeats, depth_mult);
        for r in 0..reps {
            let stride = if r == 0 { st.stride } else { 1 };
            let base = format!("block{}_{}", si + 1, r + 1);
            x = mbconv(&mut b, &base, x, st, stride);
        }
    }

    let head = b.conv_bn_act("head", x, 1, 1, 1280, Activation::Swish);
    let g = b.gap("gap", head);
    let fc = b.fc("fc1000", g, 1000);
    b.identity("prob", fc);
    b.finish()
}

/// EfficientNet-B0 (16 MBConv blocks).
pub fn efficientnet_b0(input: usize) -> Graph {
    efficientnet("EfficientNet-B0", input, 1.0)
}

/// EfficientNet-B1 (23 MBConv blocks, depth ×1.1) — Table VII's workload.
pub fn efficientnet_b1(input: usize) -> Graph {
    efficientnet("EfficientNet-B1", input, 1.1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::OpKind;

    #[test]
    fn b1_block_count() {
        let g = efficientnet_b1(256);
        let adds = g.nodes.iter().filter(|n| n.op.is_shortcut()).count();
        // B1 repeats [2,3,3,4,4,5,2] = 23 blocks, identity-shortcut blocks
        // are the non-first block of each stage: 23 - 7 = 16.
        assert_eq!(adds, 16);
    }

    #[test]
    fn b1_conv_layer_count() {
        // stem + head + fc + per-block convs (expand/dw/2 SE FCs/project).
        let g = efficientnet_b1(256);
        let n = g.conv_layer_count();
        // 23 blocks: 2 without expand (stage1) ⇒ 2*4 + 21*5 = 113, +3 = 116.
        assert_eq!(n, 116);
    }

    #[test]
    fn b1_params_about_7_8m() {
        // EfficientNet-B1: 7.8M parameters ("9 MB" 8-bit model, §I).
        let m = efficientnet_b1(256).total_weight_bytes(1) as f64 / 1e6;
        assert!((m - 7.8).abs() < 0.9, "got {m}M");
    }

    #[test]
    fn b1_gop_matches_table5() {
        // Table V: 1.38 GOP at 256×256 (0.69 GMAC).
        let gop = efficientnet_b1(256).total_gop();
        assert!((gop - 1.38).abs() < 0.25, "got {gop}");
    }

    #[test]
    fn b1_gop_scales_to_768() {
        // §I: 13.34 BFLOPS at 768×768 ⇒ ~(768/256)^2 × the 256 figure.
        let gop = efficientnet_b1(768).total_gop();
        assert!((gop - 13.34).abs() < 2.5, "got {gop}");
    }

    #[test]
    fn node_count_is_tf_like() {
        // Fig 5(a): 418 protobuf nodes for EfficientNet. Our granularity
        // (conv/bn/act separate) lands in the same regime.
        let g = efficientnet_b1(256);
        assert!(g.nodes.len() > 300, "got {}", g.nodes.len());
    }

    #[test]
    fn b0_has_16_blocks() {
        let g = efficientnet_b0(224);
        let dws = g
            .nodes
            .iter()
            .filter(|n| matches!(n.op, OpKind::Conv { depthwise: true, .. }))
            .count();
        assert_eq!(dws, 16);
    }
}
