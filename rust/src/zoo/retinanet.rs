//! RetinaNet (ResNet50 + FPN + class/box subnets) — Tables III/V and the
//! double-cut-point example of Figs 14/15.

use crate::graph::{Activation, Graph, GraphBuilder, NodeId, PadMode, Shape};

/// ResNet50 backbone up to C3/C4/C5 (no GAP/FC), returning the three
/// feature levels the FPN consumes.
fn backbone(b: &mut GraphBuilder, input_id: NodeId) -> (NodeId, NodeId, NodeId) {
    let c1 = b.conv_bn_act("conv1", input_id, 7, 2, 64, Activation::Relu);
    let mut x = b.maxpool("pool1", c1, 3, 2);

    let stage_plan: [(usize, usize); 4] = [(64, 3), (128, 4), (256, 6), (512, 3)];
    let mut taps = Vec::new();
    for (si, &(c, blocks)) in stage_plan.iter().enumerate() {
        for bi in 0..blocks {
            let stride = if si > 0 && bi == 0 { 2 } else { 1 };
            let base = format!("res{}_{}", si + 2, bi + 1);
            x = bottleneck(b, &base, x, c, stride);
        }
        taps.push(x);
    }
    (taps[1], taps[2], taps[3]) // C3, C4, C5
}

fn bottleneck(b: &mut GraphBuilder, base: &str, x: NodeId, c: usize, stride: usize) -> NodeId {
    let in_c = b.shape(x).c;
    let out_c = 4 * c;
    let c1 = b.conv_bn_act(&format!("{base}/a"), x, 1, 1, c, Activation::Relu);
    let c2 = b.conv_bn_act(&format!("{base}/b"), c1, 3, stride, c, Activation::Relu);
    let c3 = b.conv(&format!("{base}/c"), c2, 1, 1, out_c, PadMode::Same);
    let bn3 = b.batchnorm(&format!("{base}/c/bn"), c3);
    let sc = if in_c != out_c || stride != 1 {
        let p = b.conv(&format!("{base}/proj"), x, 1, stride, out_c, PadMode::Same);
        b.batchnorm(&format!("{base}/proj/bn"), p)
    } else {
        x
    };
    let add = b.add(&format!("{base}/add"), bn3, sc);
    b.activation(&format!("{base}/relu"), add, Activation::Relu)
}

/// Class + box subnets on one pyramid level: 4×(3×3-256+ReLU) each, then
/// the prediction convs (A=9 anchors, K=80 classes).
fn subnets(b: &mut GraphBuilder, level: &str, p: NodeId) {
    let mut x = p;
    for i in 0..4 {
        x = b.conv_bn_act(&format!("{level}/cls{i}"), x, 3, 1, 256, Activation::Relu);
    }
    let cls = b.conv(&format!("{level}/cls_pred"), x, 3, 1, 9 * 80, PadMode::Same);
    b.identity(&format!("{level}/cls_out"), cls);

    let mut y = p;
    for i in 0..4 {
        y = b.conv_bn_act(&format!("{level}/box{i}"), y, 3, 1, 256, Activation::Relu);
    }
    let bx = b.conv(&format!("{level}/box_pred"), y, 3, 1, 9 * 4, PadMode::Same);
    b.identity(&format!("{level}/box_out"), bx);
}

/// RetinaNet-ResNet50 at the given input size (paper uses 512×512).
///
/// FPN P3–P7 with top-down upsample+merge (the merge is channel concat +
/// 1×1 fusion — the memory-system-equivalent of the element-wise merge,
/// keeping long-path tensors off-chip as §IV-A prescribes for concat),
/// then shared class/box subnets unrolled per level.
pub fn retinanet(input: usize) -> Graph {
    let mut b = GraphBuilder::new("RetinaNet", Shape::new(input, input, 3));
    let inp = b.input_id();
    let (c3, c4, c5) = backbone(&mut b, inp);

    // Lateral 1x1s
    let p5 = b.conv("fpn/p5_lateral", c5, 1, 1, 256, PadMode::Same);
    let p5u = b.upsample("fpn/p5_up", p5, 2);
    let c4l = b.conv("fpn/p4_lateral", c4, 1, 1, 256, PadMode::Same);
    let p4m = b.add("fpn/p4_merge", c4l, p5u);
    let c3l = b.conv("fpn/p3_lateral", c3, 1, 1, 256, PadMode::Same);
    let p4u = b.upsample("fpn/p4_up", p4m, 2);
    let p3m = b.add("fpn/p3_merge", c3l, p4u);

    let p3 = b.conv("fpn/p3", p3m, 3, 1, 256, PadMode::Same);
    let p4 = b.conv("fpn/p4", p4m, 3, 1, 256, PadMode::Same);
    // P6/P7 from C5 (RetinaNet flavour)
    let p6 = b.conv("fpn/p6", c5, 3, 2, 256, PadMode::Same);
    let p6r = b.activation("fpn/p6_relu", p6, Activation::Relu);
    let p7 = b.conv("fpn/p7", p6r, 3, 2, 256, PadMode::Same);

    subnets(&mut b, "p3", p3);
    subnets(&mut b, "p4", p4);
    subnets(&mut b, "p5", p5);
    subnets(&mut b, "p6", p6);
    subnets(&mut b, "p7", p7);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_count_matches_table3_scale() {
        // Table III: 137 layers (incl. shortcut/concat etc.). Conv-only:
        // backbone 53 + FPN 7 + 5 levels × 10 = 110.
        let g = retinanet(512);
        assert_eq!(g.conv_layer_count(), 110);
        assert!(g.nodes.len() > 137, "fine-grained nodes: {}", g.nodes.len());
    }

    #[test]
    fn gop_matches_table5() {
        // Table V: 102.2 GOP at 512×512 (head config dependent — the
        // paper's converted model likely uses fewer classes; accept the
        // same order with the standard COCO 80-class/9-anchor heads).
        let gop = retinanet(512).total_gop();
        assert!(gop > 85.0 && gop < 135.0, "got {gop}");
    }

    #[test]
    fn ten_outputs() {
        // 5 pyramid levels × (cls + box).
        assert_eq!(retinanet(512).outputs().len(), 10);
    }

    #[test]
    fn pyramid_shapes() {
        let g = retinanet(512);
        let p3 = g.find("fpn/p3").unwrap();
        assert_eq!(g.node(p3).out_shape, Shape::new(64, 64, 256));
        let p7 = g.find("fpn/p7").unwrap();
        assert_eq!(g.node(p7).out_shape, Shape::new(4, 4, 256));
    }
}
