//! Int8 HWC tensors.

use crate::graph::Shape;

/// A dense int8 tensor in HWC layout (batch 1, like the accelerator's
/// feature-map memory).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Tensor {
    /// Height × width × channels.
    pub shape: Shape,
    /// Row-major HWC values.
    pub data: Vec<i8>,
}

impl Tensor {
    /// An all-zero tensor.
    pub fn zeros(shape: Shape) -> Self {
        Tensor { shape, data: vec![0; shape.numel()] }
    }

    /// Wrap existing values (length must match the shape).
    pub fn from_vec(shape: Shape, data: Vec<i8>) -> Self {
        assert_eq!(shape.numel(), data.len(), "tensor size mismatch");
        Tensor { shape, data }
    }

    /// Flat index of (y, x, c).
    #[inline]
    pub fn idx(&self, y: usize, x: usize, c: usize) -> usize {
        (y * self.shape.w + x) * self.shape.c + c
    }

    /// Value at (y, x, c); 0 outside the spatial bounds (zero padding).
    #[inline]
    pub fn at_padded(&self, y: isize, x: isize, c: usize) -> i8 {
        if y < 0 || x < 0 || y as usize >= self.shape.h || x as usize >= self.shape.w {
            0
        } else {
            self.data[self.idx(y as usize, x as usize, c)]
        }
    }

    /// Value at (y, x, c); panics outside the bounds.
    #[inline]
    pub fn at(&self, y: usize, x: usize, c: usize) -> i8 {
        self.data[self.idx(y, x, c)]
    }

    /// Store `v` at (y, x, c).
    #[inline]
    pub fn set(&mut self, y: usize, x: usize, c: usize, v: i8) {
        let i = self.idx(y, x, c);
        self.data[i] = v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_is_hwc() {
        let mut t = Tensor::zeros(Shape::new(2, 3, 4));
        t.set(1, 2, 3, 7);
        assert_eq!(t.data[(1 * 3 + 2) * 4 + 3], 7);
        assert_eq!(t.at(1, 2, 3), 7);
    }

    #[test]
    fn padding_returns_zero() {
        let t = Tensor::from_vec(Shape::new(1, 1, 1), vec![5]);
        assert_eq!(t.at_padded(-1, 0, 0), 0);
        assert_eq!(t.at_padded(0, 1, 0), 0);
        assert_eq!(t.at_padded(0, 0, 0), 5);
    }

    #[test]
    #[should_panic(expected = "size mismatch")]
    fn from_vec_checks_len() {
        Tensor::from_vec(Shape::new(2, 2, 2), vec![0; 7]);
    }
}
