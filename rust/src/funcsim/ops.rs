//! Datapath op implementations (int8 in, int32 accumulate, int8 out).

use super::tensor::Tensor;
use super::{clamp_i8, round_shift};
use crate::graph::Shape;

/// TF-style SAME padding offsets for kernel `k`, stride `s` (derived
/// from the in/out extents, so VALID shapes yield zero padding).
pub(crate) fn same_pad(in_dim: usize, out_dim: usize, k: usize, s: usize) -> isize {
    let total = ((out_dim - 1) * s + k).saturating_sub(in_dim);
    (total / 2) as isize
}

/// Normal convolution: weights HWIO, int32 accumulation, bias, shift.
///
/// Hot path (§Perf): per output pixel, accumulate into an `acc[out_c]`
/// vector with the innermost loop running over the *contiguous* `oc`
/// stride of the HWIO weight layout — auto-vectorizes and skips padded
/// taps wholesale (4.4× over the naive 6-deep scalar loop).
pub fn conv2d(
    input: &Tensor,
    out_shape: Shape,
    k: usize,
    stride: usize,
    weights: &[i8],
    bias: &[i32],
    shift: i32,
) -> Tensor {
    let mut out = Tensor::zeros(out_shape);
    conv2d_rows(input, &mut out, k, stride, weights, bias, shift, 0, out_shape.h - 1);
    out
}

/// Row-windowed [`conv2d`]: compute output rows `y0..=y1` into a
/// preallocated tensor. Same inner loops as the full op — the tiled
/// executor's bit-identity to the whole-frame reference rests on the
/// per-output-pixel independence of this arithmetic.
#[allow(clippy::too_many_arguments)]
pub(crate) fn conv2d_rows(
    input: &Tensor,
    out: &mut Tensor,
    k: usize,
    stride: usize,
    weights: &[i8],
    bias: &[i32],
    shift: i32,
    y0: usize,
    y1: usize,
) {
    let (in_c, out_c) = (input.shape.c, out.shape.c);
    assert_eq!(weights.len(), k * k * in_c * out_c, "conv weight count");
    let pad_y = same_pad(input.shape.h, out.shape.h, k, stride);
    let pad_x = same_pad(input.shape.w, out.shape.w, k, stride);
    let (in_h, in_w) = (input.shape.h as isize, input.shape.w as isize);
    let out_shape = out.shape;
    // i32 accumulators: twice the SIMD width of i64 and exactly the jnp
    // int32 accumulation of the golden model (wrapping on overflow,
    // like `jnp.dot(..., preferred_element_type=int32)`).
    let mut acc: Vec<i32> = vec![0; out_c];
    for oy in y0..=y1 {
        for ox in 0..out_shape.w {
            for (oc, a) in acc.iter_mut().enumerate() {
                *a = *bias.get(oc).unwrap_or(&0);
            }
            for ky in 0..k {
                let iy = (oy * stride) as isize + ky as isize - pad_y;
                if iy < 0 || iy >= in_h {
                    continue;
                }
                for kx in 0..k {
                    let ix = (ox * stride) as isize + kx as isize - pad_x;
                    if ix < 0 || ix >= in_w {
                        continue;
                    }
                    let in_base = input.idx(iy as usize, ix as usize, 0);
                    let xs = &input.data[in_base..in_base + in_c];
                    let w_base = (ky * k + kx) * in_c * out_c;
                    for (ic, &xv) in xs.iter().enumerate() {
                        if xv == 0 {
                            continue; // padded taps / post-ReLU zeros
                        }
                        let x = xv as i32;
                        let wrow = &weights[w_base + ic * out_c..w_base + (ic + 1) * out_c];
                        for (a, &w) in acc.iter_mut().zip(wrow) {
                            *a = a.wrapping_add(x * w as i32);
                        }
                    }
                }
            }
            let out_base = out.idx(oy, ox, 0);
            for (oc, &a) in acc.iter().enumerate() {
                out.data[out_base + oc] = clamp_i8(round_shift(a as i64, shift));
            }
        }
    }
}

/// Depthwise convolution: weights HWC (`[ky][kx][c]`).
pub fn dwconv2d(
    input: &Tensor,
    out_shape: Shape,
    k: usize,
    stride: usize,
    weights: &[i8],
    bias: &[i32],
    shift: i32,
) -> Tensor {
    let mut out = Tensor::zeros(out_shape);
    dwconv2d_rows(input, &mut out, k, stride, weights, bias, shift, 0, out_shape.h - 1);
    out
}

/// Row-windowed [`dwconv2d`] (see [`conv2d_rows`]).
#[allow(clippy::too_many_arguments)]
pub(crate) fn dwconv2d_rows(
    input: &Tensor,
    out: &mut Tensor,
    k: usize,
    stride: usize,
    weights: &[i8],
    bias: &[i32],
    shift: i32,
    y0: usize,
    y1: usize,
) {
    let c = input.shape.c;
    assert_eq!(out.shape.c, c, "depthwise preserves channels");
    assert_eq!(weights.len(), k * k * c, "dwconv weight count");
    let pad_y = same_pad(input.shape.h, out.shape.h, k, stride);
    let pad_x = same_pad(input.shape.w, out.shape.w, k, stride);
    let (in_h, in_w) = (input.shape.h as isize, input.shape.w as isize);
    let out_shape = out.shape;
    let mut acc: Vec<i64> = vec![0; c];
    for oy in y0..=y1 {
        for ox in 0..out_shape.w {
            for (ch, a) in acc.iter_mut().enumerate() {
                *a = *bias.get(ch).unwrap_or(&0) as i64;
            }
            for ky in 0..k {
                let iy = (oy * stride) as isize + ky as isize - pad_y;
                if iy < 0 || iy >= in_h {
                    continue;
                }
                for kx in 0..k {
                    let ix = (ox * stride) as isize + kx as isize - pad_x;
                    if ix < 0 || ix >= in_w {
                        continue;
                    }
                    // channel-contiguous tap: both input row and weight
                    // row stride by c
                    let in_base = input.idx(iy as usize, ix as usize, 0);
                    let xs = &input.data[in_base..in_base + c];
                    let ws = &weights[(ky * k + kx) * c..(ky * k + kx + 1) * c];
                    for ((a, &x), &w) in acc.iter_mut().zip(xs).zip(ws) {
                        *a += x as i64 * w as i64;
                    }
                }
            }
            let out_base = out.idx(oy, ox, 0);
            for (ch, &a) in acc.iter().enumerate() {
                out.data[out_base + ch] = clamp_i8(round_shift(a, shift));
            }
        }
    }
}

/// Fully connected over a 1×1×C vector: weights IO (`[cin][cout]`).
pub fn fc(input: &Tensor, out_c: usize, weights: &[i8], bias: &[i32], shift: i32) -> Tensor {
    let in_c = input.shape.c;
    assert_eq!(weights.len(), in_c * out_c, "fc weight count");
    let mut out = Tensor::zeros(Shape::vec(out_c));
    for oc in 0..out_c {
        let mut acc: i64 = *bias.get(oc).unwrap_or(&0) as i64;
        for ic in 0..in_c {
            acc += weights[ic * out_c + oc] as i64 * input.data[ic] as i64;
        }
        out.data[oc] = clamp_i8(round_shift(acc, shift));
    }
    out
}

/// SE excitation: per-channel multiply by a 1×1×C gate ("the same way as
/// the 1x1 depthwise CONV layer", §IV-A).
pub fn scale_mul(input: &Tensor, gate: &Tensor, shift: i32) -> Tensor {
    assert_eq!(gate.shape.c, input.shape.c);
    let mut out = Tensor::zeros(input.shape);
    for y in 0..input.shape.h {
        for x in 0..input.shape.w {
            for c in 0..input.shape.c {
                let acc = input.at(y, x, c) as i64 * gate.data[c] as i64;
                out.set(y, x, c, clamp_i8(round_shift(acc, shift)));
            }
        }
    }
    out
}

/// Element-wise shortcut addition of same-scale operands.
pub fn eltwise_add(a: &Tensor, b: &Tensor, shift: i32) -> Tensor {
    let mut out = Tensor::zeros(a.shape);
    eltwise_add_rows(a, b, &mut out, shift, 0, a.shape.h - 1);
    out
}

/// Row-windowed [`eltwise_add`] into a preallocated tensor.
pub(crate) fn eltwise_add_rows(
    a: &Tensor,
    b: &Tensor,
    out: &mut Tensor,
    shift: i32,
    y0: usize,
    y1: usize,
) {
    assert_eq!(a.shape, b.shape, "eltwise shape mismatch");
    let row = a.shape.w * a.shape.c;
    for i in y0 * row..(y1 + 1) * row {
        out.data[i] = clamp_i8(round_shift(a.data[i] as i64 + b.data[i] as i64, shift));
    }
}

/// Max pooling (SAME output size semantics; windows clipped at borders).
pub fn maxpool(input: &Tensor, k: usize, stride: usize) -> Tensor {
    let out_shape = input.shape.conv_same(stride, input.shape.c);
    let mut out = Tensor::zeros(out_shape);
    maxpool_rows(input, &mut out, k, stride, 0, out_shape.h - 1);
    out
}

/// Row-windowed [`maxpool`] into a preallocated tensor.
pub(crate) fn maxpool_rows(
    input: &Tensor,
    out: &mut Tensor,
    k: usize,
    stride: usize,
    y0: usize,
    y1: usize,
) {
    let out_shape = out.shape;
    let pad_y = same_pad(input.shape.h, out_shape.h, k, stride);
    let pad_x = same_pad(input.shape.w, out_shape.w, k, stride);
    for oy in y0..=y1 {
        for ox in 0..out_shape.w {
            for c in 0..input.shape.c {
                let mut m = i8::MIN;
                for ky in 0..k {
                    for kx in 0..k {
                        let iy = (oy * stride) as isize + ky as isize - pad_y;
                        let ix = (ox * stride) as isize + kx as isize - pad_x;
                        if iy >= 0
                            && ix >= 0
                            && (iy as usize) < input.shape.h
                            && (ix as usize) < input.shape.w
                        {
                            m = m.max(input.at(iy as usize, ix as usize, c));
                        }
                    }
                }
                out.set(oy, ox, c, m);
            }
        }
    }
}

/// Average pooling with rounded integer division over the *full* window
/// (hardware divides by k², zero-padding contributes zeros).
pub fn avgpool(input: &Tensor, k: usize, stride: usize) -> Tensor {
    let out_shape = input.shape.conv_same(stride, input.shape.c);
    let mut out = Tensor::zeros(out_shape);
    avgpool_rows(input, &mut out, k, stride, 0, out_shape.h - 1);
    out
}

/// Row-windowed [`avgpool`] into a preallocated tensor.
pub(crate) fn avgpool_rows(
    input: &Tensor,
    out: &mut Tensor,
    k: usize,
    stride: usize,
    y0: usize,
    y1: usize,
) {
    let out_shape = out.shape;
    let pad_y = same_pad(input.shape.h, out_shape.h, k, stride);
    let pad_x = same_pad(input.shape.w, out_shape.w, k, stride);
    let n = (k * k) as i64;
    for oy in y0..=y1 {
        for ox in 0..out_shape.w {
            for c in 0..input.shape.c {
                let mut acc: i64 = 0;
                for ky in 0..k {
                    for kx in 0..k {
                        let iy = (oy * stride) as isize + ky as isize - pad_y;
                        let ix = (ox * stride) as isize + kx as isize - pad_x;
                        acc += input.at_padded(iy, ix, c) as i64;
                    }
                }
                out.set(oy, ox, c, clamp_i8(div_round(acc, n)));
            }
        }
    }
}

/// Global average pooling to 1×1×C with rounded division.
pub fn global_avgpool(input: &Tensor) -> Tensor {
    let n = (input.shape.h * input.shape.w) as i64;
    let mut out = Tensor::zeros(Shape::vec(input.shape.c));
    for c in 0..input.shape.c {
        let mut acc: i64 = 0;
        for y in 0..input.shape.h {
            for x in 0..input.shape.w {
                acc += input.at(y, x, c) as i64;
            }
        }
        out.data[c] = clamp_i8(div_round(acc, n));
    }
    out
}

/// Round-half-away-from-zero integer division (matches
/// `np.round(a / n)` for the magnitudes involved).
fn div_round(a: i64, n: i64) -> i64 {
    if a >= 0 {
        (a + n / 2) / n
    } else {
        -((-a + n / 2) / n)
    }
}

/// Nearest-neighbour upsampling.
pub fn upsample(input: &Tensor, factor: usize) -> Tensor {
    let out_shape = input.shape.upsample(factor);
    let mut out = Tensor::zeros(out_shape);
    upsample_rows(input, &mut out, factor, 0, out_shape.h - 1);
    out
}

/// Row-windowed [`upsample`] into a preallocated tensor.
pub(crate) fn upsample_rows(input: &Tensor, out: &mut Tensor, factor: usize, y0: usize, y1: usize) {
    let out_shape = out.shape;
    for y in y0..=y1 {
        for x in 0..out_shape.w {
            for c in 0..input.shape.c {
                out.set(y, x, c, input.at(y / factor, x / factor, c));
            }
        }
    }
}

/// Channel concatenation.
pub fn concat(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!((a.shape.h, a.shape.w), (b.shape.h, b.shape.w));
    let out_shape = Shape::new(a.shape.h, a.shape.w, a.shape.c + b.shape.c);
    let mut out = Tensor::zeros(out_shape);
    for y in 0..a.shape.h {
        for x in 0..a.shape.w {
            for c in 0..a.shape.c {
                out.set(y, x, c, a.at(y, x, c));
            }
            for c in 0..b.shape.c {
                out.set(y, x, a.shape.c + c, b.at(y, x, c));
            }
        }
    }
    out
}

/// ReLU on int8.
pub fn relu(t: &mut Tensor) {
    let last = t.shape.h - 1;
    relu_rows(t, 0, last);
}

/// Row-windowed [`relu`].
pub(crate) fn relu_rows(t: &mut Tensor, y0: usize, y1: usize) {
    let row = t.shape.w * t.shape.c;
    for v in t.data[y0 * row..(y1 + 1) * row].iter_mut() {
        *v = (*v).max(0);
    }
}

/// Hardware leaky-ReLU: negative values are arithmetically shifted right
/// by 3 (slope 1/8).
pub fn leaky(t: &mut Tensor) {
    let last = t.shape.h - 1;
    leaky_rows(t, 0, last);
}

/// Row-windowed [`leaky`].
pub(crate) fn leaky_rows(t: &mut Tensor, y0: usize, y1: usize) {
    let row = t.shape.w * t.shape.c;
    for v in t.data[y0 * row..(y1 + 1) * row].iter_mut() {
        if *v < 0 {
            *v >>= 3;
        }
    }
}

/// LUT activation: index by the unsigned reinterpretation of the int8.
pub fn lut_act(t: &mut Tensor, lut: &[i8]) {
    let last = t.shape.h - 1;
    lut_rows(t, lut, 0, last);
}

/// Row-windowed [`lut_act`].
pub(crate) fn lut_rows(t: &mut Tensor, lut: &[i8], y0: usize, y1: usize) {
    debug_assert_eq!(lut.len(), 256);
    let row = t.shape.w * t.shape.c;
    for v in t.data[y0 * row..(y1 + 1) * row].iter_mut() {
        *v = lut[*v as u8 as usize];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_identity_kernel() {
        // 1x1 conv with identity weights and shift 0 copies the input.
        let input = Tensor::from_vec(Shape::new(2, 2, 2), vec![1, 2, 3, 4, 5, 6, 7, 8]);
        let mut w = vec![0i8; 2 * 2];
        w[0] = 1; // w[ic=0][oc=0]
        w[3] = 1; // w[ic=1][oc=1]
        let out = conv2d(&input, Shape::new(2, 2, 2), 1, 1, &w, &[0, 0], 0);
        assert_eq!(out.data, input.data);
    }

    #[test]
    fn conv_matches_hand_computation() {
        // 3x3 all-ones kernel on a 3x3 single-channel ramp, SAME pad.
        let input = Tensor::from_vec(Shape::new(3, 3, 1), (1..=9).map(|v| v as i8).collect());
        let w = vec![1i8; 9];
        let out = conv2d(&input, Shape::new(3, 3, 1), 3, 1, &w, &[0], 0);
        // center = sum 1..9 = 45; corner (0,0) = 1+2+4+5 = 12
        assert_eq!(out.at(1, 1, 0), 45);
        assert_eq!(out.at(0, 0, 0), 12);
    }

    #[test]
    fn conv_shift_and_clamp() {
        let input = Tensor::from_vec(Shape::new(1, 1, 1), vec![100]);
        let out = conv2d(&input, Shape::new(1, 1, 1), 1, 1, &[100], &[0], 0);
        assert_eq!(out.data[0], 127); // 10000 clamps
        let out2 = conv2d(&input, Shape::new(1, 1, 1), 1, 1, &[100], &[0], 7);
        assert_eq!(out2.data[0], 78); // 10000/128 = 78.125 -> 78
    }

    #[test]
    fn dwconv_is_per_channel() {
        let input = Tensor::from_vec(Shape::new(1, 1, 2), vec![3, 5]);
        let out = dwconv2d(&input, Shape::new(1, 1, 2), 1, 1, &[2, 4], &[0, 0], 0);
        assert_eq!(out.data, vec![6, 20]);
    }

    #[test]
    fn stride_two_downsamples() {
        let input = Tensor::from_vec(Shape::new(4, 4, 1), (0..16).map(|v| v as i8).collect());
        let out = maxpool(&input, 2, 2);
        assert_eq!(out.shape, Shape::new(2, 2, 1));
        assert_eq!(out.data, vec![5, 7, 13, 15]);
    }

    #[test]
    fn gap_rounds() {
        let input = Tensor::from_vec(Shape::new(2, 2, 1), vec![1, 2, 3, 5]);
        let out = global_avgpool(&input);
        assert_eq!(out.data, vec![3]); // 11/4 = 2.75 -> 3
    }

    #[test]
    fn eltwise_saturates() {
        let a = Tensor::from_vec(Shape::new(1, 1, 1), vec![100]);
        let b = Tensor::from_vec(Shape::new(1, 1, 1), vec![100]);
        assert_eq!(eltwise_add(&a, &b, 0).data, vec![127]);
        assert_eq!(eltwise_add(&a, &b, 1).data, vec![100]);
    }

    #[test]
    fn leaky_shifts_negatives() {
        let mut t = Tensor::from_vec(Shape::new(1, 1, 3), vec![-64, -1, 5]);
        leaky(&mut t);
        assert_eq!(t.data, vec![-8, -1, 5]); // -1 >> 3 = -1 (arithmetic)
    }

    #[test]
    fn upsample_replicates() {
        let t = Tensor::from_vec(Shape::new(1, 2, 1), vec![7, 9]);
        let u = upsample(&t, 2);
        assert_eq!(u.shape, Shape::new(2, 4, 1));
        assert_eq!(u.data, vec![7, 7, 9, 9, 7, 7, 9, 9]);
    }

    #[test]
    fn concat_channels() {
        let a = Tensor::from_vec(Shape::new(1, 1, 2), vec![1, 2]);
        let b = Tensor::from_vec(Shape::new(1, 1, 1), vec![3]);
        assert_eq!(concat(&a, &b).data, vec![1, 2, 3]);
    }

    #[test]
    fn lut_uses_unsigned_index() {
        let mut lut = vec![0i8; 256];
        lut[5] = 50; // q = 5
        lut[251] = -50; // q = -5 -> index 251
        let mut t = Tensor::from_vec(Shape::new(1, 1, 2), vec![5, -5]);
        lut_act(&mut t, &lut);
        assert_eq!(t.data, vec![50, -50]);
    }
}
