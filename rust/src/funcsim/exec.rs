//! Graph execution with the fused-datapath semantics.
//!
//! Values are computed node-by-node in topological order; each node's
//! arithmetic matches what the accelerator's fused group applies at the
//! corresponding pipeline stage (fusion is order-preserving, so the
//! node-level walk is bit-identical to group-level execution). The
//! executor cross-checks the lowered instruction stream's geometry
//! against the graph as it goes — decode errors or mismatched shapes
//! fail the run.

use super::ops;
use super::params::Params;
use super::tensor::Tensor;
use crate::analyzer::GroupedGraph;
use crate::graph::{Activation, Node, NodeId, OpKind};
use crate::isa::InstructionStream;
use std::fmt;

/// Execution failure.
#[derive(Debug, Clone)]
pub struct ExecError(pub String);

impl fmt::Display for ExecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "funcsim: {}", self.0)
    }
}

impl std::error::Error for ExecError {}

/// The functional simulator.
pub struct Executor<'a> {
    /// The analyzed network to execute.
    pub gg: &'a GroupedGraph,
    /// Quantized parameters, keyed by group main-node name.
    pub params: &'a Params,
}

impl<'a> Executor<'a> {
    /// An executor over one analyzed network and its parameters.
    pub fn new(gg: &'a GroupedGraph, params: &'a Params) -> Self {
        Executor { gg, params }
    }

    /// Parameters of the group containing `node`, looked up by the
    /// group's main-node name.
    pub(crate) fn group_params(&self, node: NodeId) -> Option<&super::params::GroupParams> {
        let gid = self.gg.node_group[node.0];
        let main = self.gg.groups[gid.0].main;
        self.params.get(&self.gg.graph.node(main).name)
    }

    /// Run the network on `input`; returns one value slot per graph node.
    pub fn run(&self, input: &Tensor) -> Result<Vec<Tensor>, ExecError> {
        let g = &self.gg.graph;
        if input.shape != g.input().out_shape {
            return Err(ExecError(format!(
                "input shape {} != graph input {}",
                input.shape,
                g.input().out_shape
            )));
        }
        let mut values: Vec<Option<Tensor>> = vec![None; g.nodes.len()];
        for (ni, node) in g.nodes.iter().enumerate() {
            let out = self.compute_node(node, &values, input)?;
            values[ni] = Some(out);
        }
        Ok(values.into_iter().map(Option::unwrap).collect())
    }

    /// Compute one node's full output from already-computed `values`
    /// (indexed by node id). Shared by the whole-frame walk above and
    /// the per-tile walk in [`crate::tile::exec`].
    pub(crate) fn compute_node(
        &self,
        node: &Node,
        values: &[Option<Tensor>],
        input: &Tensor,
    ) -> Result<Tensor, ExecError> {
        let val = |id: NodeId| -> Result<&Tensor, ExecError> {
            values[id.0]
                .as_ref()
                .ok_or_else(|| ExecError(format!("value of node {} missing", id.0)))
        };
        let out = match node.op {
            OpKind::Input => input.clone(),
            OpKind::Conv { k, stride, depthwise, .. } => {
                let gp = self
                    .group_params(node.id)
                    .ok_or_else(|| ExecError(format!("no params for {}", node.name)))?;
                let x = val(node.inputs[0])?;
                if depthwise {
                    ops::dwconv2d(x, node.out_shape, k, stride, &gp.weights, &gp.bias, gp.shift)
                } else {
                    ops::conv2d(x, node.out_shape, k, stride, &gp.weights, &gp.bias, gp.shift)
                }
            }
            OpKind::Fc { out_c } => {
                let gp = self
                    .group_params(node.id)
                    .ok_or_else(|| ExecError(format!("no params for {}", node.name)))?;
                ops::fc(val(node.inputs[0])?, out_c, &gp.weights, &gp.bias, gp.shift)
            }
            // Batch-norm / bias are folded into the conv's int32 bias
            // and requant shift at quantization time.
            OpKind::BatchNorm | OpKind::BiasAdd | OpKind::Identity => val(node.inputs[0])?.clone(),
            OpKind::Act(a) => {
                let mut t = val(node.inputs[0])?.clone();
                self.apply_act(&mut t, a, node.id)?;
                t
            }
            OpKind::MaxPool { k, stride } => ops::maxpool(val(node.inputs[0])?, k, stride),
            OpKind::AvgPool { k, stride } => ops::avgpool(val(node.inputs[0])?, k, stride),
            OpKind::GlobalAvgPool => ops::global_avgpool(val(node.inputs[0])?),
            OpKind::EltwiseAdd => {
                let shift = self.group_params(node.id).map(|p| p.elt_shift).unwrap_or(0);
                ops::eltwise_add(val(node.inputs[0])?, val(node.inputs[1])?, shift)
            }
            OpKind::ScaleMul => {
                let shift = self.group_params(node.id).map(|p| p.shift).unwrap_or(7);
                ops::scale_mul(val(node.inputs[0])?, val(node.inputs[1])?, shift)
            }
            OpKind::Concat => ops::concat(val(node.inputs[0])?, val(node.inputs[1])?),
            OpKind::Upsample { factor } => ops::upsample(val(node.inputs[0])?, factor),
        };
        if out.shape != node.out_shape {
            return Err(ExecError(format!(
                "node {} produced {} expected {}",
                node.name, out.shape, node.out_shape
            )));
        }
        Ok(out)
    }

    fn apply_act(&self, t: &mut Tensor, a: Activation, node: NodeId) -> Result<(), ExecError> {
        match a {
            Activation::Linear => {}
            Activation::Relu => ops::relu(t),
            Activation::Leaky => ops::leaky(t),
            Activation::Relu6
            | Activation::Swish
            | Activation::Sigmoid
            | Activation::HardSwish
            | Activation::HardSigmoid => {
                let gp = self
                    .group_params(node)
                    .and_then(|p| p.lut.as_ref())
                    .ok_or_else(|| {
                        ExecError(format!(
                            "activation {a:?} at node {} requires a LUT",
                            node.0
                        ))
                    })?;
                ops::lut_act(t, gp);
            }
        }
        Ok(())
    }

    /// Output tensor of a group (its last node's value).
    pub fn group_output<'v>(
        &self,
        values: &'v [Tensor],
        gid: crate::analyzer::GroupId,
    ) -> &'v Tensor {
        let last = *self.gg.groups[gid.0].nodes.last().unwrap();
        &values[last.0]
    }
}

/// Convenience: validate the lowered stream against the graph, then run.
pub fn execute(
    gg: &GroupedGraph,
    stream: &InstructionStream,
    params: &Params,
    input: &Tensor,
) -> Result<Vec<Tensor>, ExecError> {
    // geometry cross-check: every instruction matches its group
    if stream.instrs.len() != gg.groups.len() {
        return Err(ExecError("instruction count != group count".into()));
    }
    for (ins, gr) in stream.instrs.iter().zip(&gg.groups) {
        let (k, s, _) = gr.conv_geometry(&gg.graph);
        if ins.group as usize != gr.id.0
            || ins.k as usize != k
            || ins.stride as usize != s
            || ins.in_h as usize != gr.in_shape.h
            || ins.in_c as usize != gr.in_shape.c
            || ins.out_h as usize != gr.out_shape.h
            || ins.out_c as usize != gr.out_shape.c
        {
            return Err(ExecError(format!("instruction {} disagrees with group", gr.id.0)));
        }
    }
    Executor::new(gg, params).run(input)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analyzer::analyze;
    use crate::graph::{GraphBuilder, PadMode, Shape};
    use crate::isa::{lower, MemAssign};
    use crate::testutil::Rng;

    fn tiny_resnet_like() -> GroupedGraph {
        let mut b = GraphBuilder::new("tiny", Shape::new(8, 8, 4));
        let x = b.input_id();
        let c1 = b.conv("c1", x, 3, 1, 8, PadMode::Same);
        let r1 = b.activation("c1/relu", c1, crate::graph::Activation::Relu);
        let c2 = b.conv("c2", r1, 3, 1, 8, PadMode::Same);
        let add = b.add("add", c2, r1);
        let r2 = b.activation("add/relu", add, crate::graph::Activation::Relu);
        let g1 = b.gap("gap", r2);
        let _f = b.fc("fc", g1, 10);
        analyze(&b.finish())
    }

    #[test]
    fn runs_tiny_network_end_to_end() {
        let gg = tiny_resnet_like();
        let params = Params::random(&gg, 1);
        let mut rng = Rng::from_seed(2);
        let input = Tensor::from_vec(Shape::new(8, 8, 4), rng.i8_vec(8 * 8 * 4));
        let assigns = vec![MemAssign::default(); gg.groups.len()];
        let stream = lower(&gg, &assigns);
        let values = execute(&gg, &stream, &params, &input).unwrap();
        let out = &values[gg.graph.find("fc").unwrap().0];
        assert_eq!(out.shape, Shape::vec(10));
    }

    #[test]
    fn deterministic_across_runs() {
        let gg = tiny_resnet_like();
        let params = Params::random(&gg, 3);
        let mut rng = Rng::from_seed(4);
        let input = Tensor::from_vec(Shape::new(8, 8, 4), rng.i8_vec(8 * 8 * 4));
        let e = Executor::new(&gg, &params);
        let a = e.run(&input).unwrap();
        let b = e.run(&input).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn missing_params_is_an_error() {
        let gg = tiny_resnet_like();
        let params = Params::default();
        let input = Tensor::zeros(Shape::new(8, 8, 4));
        let err = Executor::new(&gg, &params).run(&input).unwrap_err();
        assert!(err.0.contains("no params"), "{err}");
    }

    #[test]
    fn wrong_input_shape_is_an_error() {
        let gg = tiny_resnet_like();
        let params = Params::random(&gg, 1);
        let input = Tensor::zeros(Shape::new(4, 4, 4));
        assert!(Executor::new(&gg, &params).run(&input).is_err());
    }

    #[test]
    fn shortcut_actually_contributes() {
        // Zeroing c2's weights must make the residual output equal the
        // ReLU'd shortcut branch.
        let gg = tiny_resnet_like();
        let mut params = Params::random(&gg, 5);
        {
            let c2 = params.groups.get_mut("c2").unwrap();
            c2.weights.iter_mut().for_each(|w| *w = 0);
            c2.bias.iter_mut().for_each(|b| *b = 0);
        }
        let mut rng = Rng::from_seed(6);
        let input = Tensor::from_vec(Shape::new(8, 8, 4), rng.i8_vec(8 * 8 * 4));
        let e = Executor::new(&gg, &params);
        let values = e.run(&input).unwrap();
        let r1 = &values[gg.graph.find("c1/relu").unwrap().0];
        let r2 = &values[gg.graph.find("add/relu").unwrap().0];
        assert_eq!(r1.data, r2.data);
    }

    #[test]
    fn zoo_models_execute_with_random_params() {
        // Robustness: small-input EfficientNet-B0 (SE path, LUTs, dw) and
        // ResNet18 run end to end.
        for (name, input) in [("efficientnet-b0", 64), ("resnet18", 64)] {
            let gg = analyze(&crate::zoo::by_name(name, input).unwrap());
            let params = Params::random(&gg, 7);
            let mut rng = Rng::from_seed(8);
            let t = Tensor::from_vec(Shape::new(input, input, 3), rng.i8_vec(input * input * 3));
            let values = Executor::new(&gg, &params).run(&t).unwrap();
            assert_eq!(values.len(), gg.graph.nodes.len(), "{name}");
        }
    }
}
