//! Functional instruction-stream simulator.
//!
//! Executes a compiled [`crate::isa::InstructionStream`] over int8
//! tensors with the accelerator's exact datapath semantics: int32
//! accumulation, per-group dynamic-fixed-point requantization shifts
//! (§III-B: "the proposed design supports a dynamic fixed point format"),
//! and 8-bit look-up tables for swish/sigmoid ("implemented using an
//! 8-bit look-up table"). This is the "unified software reference code
//! for hardware verification" of Fig. 4 — the e2e example checks it
//! bit-exactly against the JAX golden model executed through PJRT.
//!
//! Arithmetic contract (shared with `python/compile/model.py` — keep in
//! sync, the e2e test enforces it):
//! * conv/fc: `acc_i32 = Σ w_i8 · x_i8 + bias_i32`, then
//!   `out = clamp(round_shift(acc, shift))` with
//!   `round_shift(a, s) = (a + (1 << (s-1))) >> s` for `s > 0`;
//! * ReLU family acts on the int8 domain; swish/sigmoid index a
//!   256-entry LUT with the unsigned reinterpretation of the int8 value;
//! * eltwise add: int32 sum of same-scale operands, round-shifted;
//! * SE scale: `x_i8 · gate_i8` per channel, round-shifted;
//! * avg/global pooling: int32 sum, rounded division by the window size.

mod tensor;
mod params;
pub(crate) mod ops;
mod exec;

pub use exec::{execute, ExecError, Executor};
pub use params::{GroupParams, Params};
pub use tensor::Tensor;

/// Round-to-nearest (ties away from zero for non-negative accumulators)
/// arithmetic right shift; negative shifts are left shifts.
#[inline]
pub fn round_shift(acc: i64, shift: i32) -> i64 {
    if shift > 0 {
        (acc + (1i64 << (shift - 1))) >> shift
    } else {
        acc << (-shift)
    }
}

/// Saturate an accumulator into int8.
#[inline]
pub fn clamp_i8(v: i64) -> i8 {
    v.clamp(-128, 127) as i8
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_shift_rounds_to_nearest() {
        assert_eq!(round_shift(7, 2), 2); // 1.75 -> 2
        assert_eq!(round_shift(5, 2), 1); // 1.25 -> 1
        assert_eq!(round_shift(6, 2), 2); // 1.5  -> 2 (ties up)
        assert_eq!(round_shift(-5, 2), -1); // -1.25 -> -1
        assert_eq!(round_shift(3, 0), 3);
        assert_eq!(round_shift(3, -2), 12);
    }

    #[test]
    fn clamp_saturates() {
        assert_eq!(clamp_i8(300), 127);
        assert_eq!(clamp_i8(-300), -128);
        assert_eq!(clamp_i8(-5), -5);
    }
}
