//! Quantized parameter store (weights / biases / shifts / LUTs).
//!
//! The compile path (python `aot.py`) exports a JSON parameter file next
//! to the HLO artifact; the same file drives the functional simulator so
//! both sides compute from identical integers.
//!
//! Format:
//! ```json
//! { "groups": { "<group name>": {
//!     "weights": [..int8..],      // HWIO for conv, IO for fc
//!     "bias":    [..int32..],     // per output channel
//!     "shift":   7,               // requant shift after accumulate
//!     "lut":     [..256 x int8..] // optional, for swish/sigmoid
//! }}}
//! ```

use crate::serialize::{parse, Json};
use crate::testutil::Rng;
use crate::compiler::CompileError;
use crate::Result;
use std::collections::HashMap;

/// Per-group quantized parameters.
#[derive(Debug, Clone, Default)]
pub struct GroupParams {
    /// Conv: `[kh][kw][cin][cout]` flattened (HWIO); FC: `[cin][cout]`.
    pub weights: Vec<i8>,
    /// Per-output-channel int32 bias added to the accumulator.
    pub bias: Vec<i32>,
    /// Requantization shift applied to the accumulator.
    pub shift: i32,
    /// Shift applied to a fused element-wise addition (usually 0).
    pub elt_shift: i32,
    /// 256-entry activation LUT (swish / sigmoid).
    pub lut: Option<Vec<i8>>,
}

/// All parameters for one compiled network, keyed by the *main node
/// name* of each group (stable across the rust/python graph builders).
#[derive(Debug, Clone, Default)]
pub struct Params {
    /// Per-group parameters, keyed by main-node name.
    pub groups: HashMap<String, GroupParams>,
}

impl Params {
    /// Parameters of the group whose main node has this name.
    pub fn get(&self, name: &str) -> Option<&GroupParams> {
        self.groups.get(name)
    }

    /// Parse from the JSON interchange format.
    pub fn from_json(doc: &Json) -> Result<Params> {
        let obj = doc
            .get("groups")
            .ok_or_else(|| CompileError::params("params: missing groups"))?;
        let Json::Obj(map) = obj else {
            return Err(CompileError::params("params: groups must be an object"));
        };
        let mut groups = HashMap::new();
        for (name, g) in map {
            let ints = |key: &str| -> Result<Vec<i64>> {
                match g.get(key) {
                    None => Ok(Vec::new()),
                    Some(Json::Arr(a)) => a
                        .iter()
                        .map(|v| {
                            v.as_f64().filter(|f| f.fract() == 0.0).map(|f| f as i64).ok_or_else(
                                || {
                                    CompileError::params(format!(
                                        "params {name}.{key}: non-integer"
                                    ))
                                },
                            )
                        })
                        .collect(),
                    Some(_) => {
                        Err(CompileError::params(format!("params {name}.{key}: expected array")))
                    }
                }
            };
            let weights: Vec<i8> = ints("weights")?
                .into_iter()
                .map(|v| {
                    i8::try_from(v)
                        .map_err(|_| CompileError::params(format!("{name}: weight out of i8")))
                })
                .collect::<Result<_>>()?;
            let bias: Vec<i32> = ints("bias")?
                .into_iter()
                .map(|v| {
                    i32::try_from(v)
                        .map_err(|_| CompileError::params(format!("{name}: bias out of i32")))
                })
                .collect::<Result<_>>()?;
            let lut_raw = ints("lut")?;
            let lut = if lut_raw.is_empty() {
                None
            } else {
                if lut_raw.len() != 256 {
                    return Err(CompileError::params(format!("{name}: LUT must have 256 entries")));
                }
                Some(
                    lut_raw
                        .into_iter()
                        .map(|v| {
                            i8::try_from(v)
                                .map_err(|_| CompileError::params(format!("{name}: lut out of i8")))
                        })
                        .collect::<Result<_>>()?,
                )
            };
            let shift = g.get("shift").and_then(Json::as_f64).unwrap_or(0.0) as i32;
            let elt_shift = g.get("elt_shift").and_then(Json::as_f64).unwrap_or(0.0) as i32;
            groups.insert(
                name.clone(),
                GroupParams { weights, bias, shift, elt_shift, lut },
            );
        }
        Ok(Params { groups })
    }

    /// Load from a JSON parameter file (the python export format).
    pub fn from_file(path: &std::path::Path) -> Result<Params> {
        let text =
            std::fs::read_to_string(path).map_err(|e| CompileError::io(path, e))?;
        let doc = parse(&text)
            .map_err(|e| CompileError::parse(format!("{}: {e}", path.display())))?;
        Self::from_json(&doc)
    }

    /// Deterministic random parameters for a grouped graph (robustness
    /// and property tests; real runs use python-exported parameters).
    pub fn random(gg: &crate::analyzer::GroupedGraph, seed: u64) -> Params {
        let mut rng = Rng::from_seed(seed);
        let mut groups = HashMap::new();
        for gr in &gg.groups {
            let wcount: u64 = gr
                .nodes
                .iter()
                .map(|&n| gg.graph.node(n).weight_count())
                .sum();
            if wcount == 0 && gr.shortcut_of.is_none() && !gr.act.lut_evaluated() {
                continue;
            }
            // small weights keep accumulators informative but bounded
            let weights: Vec<i8> = (0..wcount).map(|_| (rng.below(15) as i8) - 7).collect();
            let out_c = gr.out_shape.c;
            let bias: Vec<i32> = (0..out_c).map(|_| (rng.below(64) as i32) - 32).collect();
            let lut = if gr.act.lut_evaluated() {
                Some((0..256).map(|i| ((i as i64 * 7 + seed as i64) % 255 - 127) as i8).collect())
            } else {
                None
            };
            let name = gg.graph.node(gr.main).name.clone();
            groups.insert(
                name,
                GroupParams { weights, bias, shift: 7, elt_shift: 0, lut },
            );
        }
        Params { groups }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_round_trip() {
        let doc = parse(
            r#"{"groups":{"conv1":{"weights":[1,-2,3],"bias":[10,-10],"shift":7},
                          "act1":{"lut":[0],"shift":0}}}"#,
        )
        .unwrap();
        // act1 has a 1-entry LUT -> error
        assert!(Params::from_json(&doc).is_err());

        let lut: Vec<String> = (0..256).map(|i| (i % 127).to_string()).collect();
        let text = format!(
            r#"{{"groups":{{"conv1":{{"weights":[1,-2,3],"bias":[10,-10],"shift":7}},
                 "act1":{{"lut":[{}],"shift":0}}}}}}"#,
            lut.join(",")
        );
        let p = Params::from_json(&parse(&text).unwrap()).unwrap();
        assert_eq!(p.get("conv1").unwrap().weights, vec![1, -2, 3]);
        assert_eq!(p.get("conv1").unwrap().shift, 7);
        assert_eq!(p.get("act1").unwrap().lut.as_ref().unwrap().len(), 256);
    }

    #[test]
    fn rejects_out_of_range() {
        let doc = parse(r#"{"groups":{"c":{"weights":[200]}}}"#).unwrap();
        assert!(Params::from_json(&doc).is_err());
    }

    #[test]
    fn random_params_cover_weighted_groups() {
        let gg = crate::analyzer::analyze(&crate::zoo::resnet18(32));
        let p = Params::random(&gg, 42);
        for gr in gg.compute_groups() {
            if gr.weight_bytes(&gg.graph, 1) > 0 {
                let name = &gg.graph.node(gr.main).name;
                let gp = p.get(name).unwrap_or_else(|| panic!("missing {name}"));
                assert_eq!(gp.weights.len() as u64, gr.weight_bytes(&gg.graph, 1));
            }
        }
    }
}
