//! End-to-end test over the real artifacts: funcsim vs the PJRT-executed
//! golden model (the same check as `examples/e2e_verify.rs`, as a test).
//!
//! Requires `make artifacts` plus the `pjrt` feature (skips gracefully
//! when artifacts are absent or the runtime is stubbed out, so plain
//! `cargo test` works in a fresh checkout).

use shortcutfusion::compiler::{CompileError, Compiler};
use shortcutfusion::config::AccelConfig;
use shortcutfusion::funcsim::{execute, Params};
use shortcutfusion::runtime::{load_expected_logits, load_input_tensor, Runtime};
use shortcutfusion::zoo;
use std::path::PathBuf;

fn artifacts() -> Option<PathBuf> {
    for dir in ["artifacts", "../artifacts"] {
        let p = PathBuf::from(dir);
        if p.join("tinynet.hlo.txt").exists() {
            return Some(p);
        }
    }
    None
}

#[test]
fn funcsim_matches_pjrt_bit_exactly() {
    let Some(dir) = artifacts() else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    };
    let cfg = AccelConfig::kcu1500_int8();
    let r = Compiler::new(cfg).compile(&zoo::tinynet()).unwrap();
    let params = Params::from_file(&dir.join("tinynet_params.json")).unwrap();
    let input = load_input_tensor(&dir.join("tinynet_input.json")).unwrap();

    let values = execute(&r.grouped, &r.stream, &params, &input).unwrap();
    let fc = r.grouped.graph.find("fc").unwrap();
    let funcsim_logits = values[fc.0].data.clone();

    let expected = load_expected_logits(&dir.join("tinynet_expected.json")).unwrap();
    let mut rt = match Runtime::cpu() {
        Ok(rt) => rt,
        Err(CompileError::Unsupported(_)) => {
            eprintln!("SKIP PJRT half: built without the `pjrt` feature");
            assert_eq!(funcsim_logits, expected, "funcsim vs export-time expectation");
            return;
        }
        Err(e) => panic!("PJRT client failed: {e}"),
    };
    let id = rt.load(&dir.join("tinynet.hlo.txt")).unwrap();
    let pjrt_logits = rt.run_i8(id, &[&input]).unwrap();

    assert_eq!(pjrt_logits, expected, "PJRT vs export-time expectation");
    assert_eq!(funcsim_logits, pjrt_logits, "funcsim vs PJRT bit-exactness");
}

#[test]
fn matmul_artifact_matches_naive_reference() {
    let Some(dir) = artifacts() else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    };
    use shortcutfusion::funcsim::Tensor;
    use shortcutfusion::graph::Shape;
    use shortcutfusion::testutil::Rng;

    let mut rt = match Runtime::cpu() {
        Ok(rt) => rt,
        Err(CompileError::Unsupported(_)) => {
            eprintln!("SKIP: built without the `pjrt` feature");
            return;
        }
        Err(e) => panic!("PJRT client failed: {e}"),
    };
    let id = rt.load(&dir.join("matmul64.hlo.txt")).unwrap();
    let mut rng = Rng::from_seed(77);
    let a = rng.i8_vec(64 * 64);
    let b = rng.i8_vec(64 * 64);
    let got = rt
        .run_i8_to_i32(
            id,
            &[
                &Tensor::from_vec(Shape::new(64, 64, 1), a.clone()),
                &Tensor::from_vec(Shape::new(64, 64, 1), b.clone()),
            ],
        )
        .unwrap();
    for i in 0..64 {
        for j in 0..64 {
            let mut s = 0i32;
            for k in 0..64 {
                s += a[i * 64 + k] as i32 * b[k * 64 + j] as i32;
            }
            assert_eq!(got[i * 64 + j], s, "({i},{j})");
        }
    }
}

#[test]
fn runtime_compile_cache_hits() {
    let Some(dir) = artifacts() else {
        eprintln!("SKIP: artifacts not built");
        return;
    };
    let mut rt = match Runtime::cpu() {
        Ok(rt) => rt,
        Err(CompileError::Unsupported(_)) => {
            eprintln!("SKIP: built without the `pjrt` feature");
            return;
        }
        Err(e) => panic!("PJRT client failed: {e}"),
    };
    let a = rt.load(&dir.join("matmul64.hlo.txt")).unwrap();
    let b = rt.load(&dir.join("matmul64.hlo.txt")).unwrap();
    assert_eq!(a, b, "same artifact must hit the compile cache");
}

#[test]
fn runtime_reports_missing_artifact() {
    // With the stub runtime, cpu() itself is the (typed) failure.
    match Runtime::cpu() {
        Ok(mut rt) => assert!(rt.load(std::path::Path::new("artifacts/nope.hlo.txt")).is_err()),
        Err(e) => assert!(matches!(e, CompileError::Unsupported(_))),
    }
}
