//! Explorer integration tests.
//!
//! The centrepiece reproduces the paper's buffer-size ablation (Fig. 16
//! discussion, Table II): with a generous SRAM budget the frame-reuse
//! endpoint is feasible and the cut-point optimizer matches or beats both
//! fixed schemes; as the budget shrinks past the frame endpoint's
//! requirement the optimizer crosses over to row-heavier mixed policies
//! while still beating fixed-row; below the minimum-buffer point nothing
//! fits and the explorer says so. The recommended configuration then
//! round-trips through `Compiler::pack` into a loadable `Program`.

use std::sync::Arc;

use shortcutfusion::compiler::{
    FixedReuseStrategy, MinBufferStrategy, ReuseStrategy, Session,
};
use shortcutfusion::config::AccelConfig;
use shortcutfusion::explorer::SearchSpace;
use shortcutfusion::isa::ReuseMode;
use shortcutfusion::program::Program;

/// The ablation isolates the `sram_budget` axis: BRAM is made a
/// non-constraint so eq-(10) feasibility is decided by the byte budget
/// alone.
fn ablation_base() -> AccelConfig {
    let mut cfg = AccelConfig::kcu1500_int8();
    cfg.bram18k_total = 1_000_000;
    cfg
}

const MODEL: &str = "resnet18";
const INPUT: usize = 224;

#[test]
fn buffer_budget_ablation_reproduces_the_crossover() {
    let base = ablation_base();
    let session = Session::new();

    // Budget-independent costs of the fixed endpoints and the
    // minimum-buffer point (the budget only gates feasibility).
    let row: Arc<dyn ReuseStrategy> = Arc::new(FixedReuseStrategy(ReuseMode::Row));
    let frame: Arc<dyn ReuseStrategy> = Arc::new(FixedReuseStrategy(ReuseMode::Frame));
    let minb: Arc<dyn ReuseStrategy> = Arc::new(MinBufferStrategy);
    let r = session.compile_with(MODEL, INPUT, &base, &row).unwrap();
    let f = session.compile_with(MODEL, INPUT, &base, &frame).unwrap();
    let min_need = session
        .compile_with(MODEL, INPUT, &base, &minb)
        .unwrap()
        .evaluation
        .sram
        .total;
    let row_need = r.evaluation.sram.total;
    let frame_need = f.evaluation.sram.total;

    // Frame reuse buffers whole output frames (eq. 4); row reuse only
    // needs the largest whole-layer weight preload (eq. 1) plus the
    // six-row circular buffer — at 224×224 the frame side costs far more
    // SRAM but keeps the shortcut feature maps on chip.
    assert!(frame_need > row_need, "frame {frame_need} !> row {row_need}");
    assert!(
        f.evaluation.dram.total < r.evaluation.dram.total,
        "frame must trade SRAM for DRAM traffic"
    );
    assert!(min_need <= row_need);

    // Three budgets around the two thresholds.
    let generous = frame_need + frame_need / 4;
    let mid = (frame_need + row_need) / 2;
    let tiny = min_need / 2;

    let exploration = SearchSpace::new(base)
        .model(MODEL)
        .input_sizes(&[INPUT])
        .sram_budgets(&[generous, mid, tiny])
        .ablation_strategies() // cutpoint, fixed-row, fixed-frame, tile
        .explore(&session, 4)
        .unwrap();
    assert_eq!(exploration.points.len(), 12);
    assert!(exploration.failures.is_empty());
    let get = |strategy: &str, budget: usize| {
        exploration
            .points
            .iter()
            .find(|p| p.strategy_name() == strategy && p.cfg.sram_budget == budget)
            .unwrap()
    };

    // Generous budget: both endpoints fit, and they are corners of the
    // optimizer's cut space, so the cut-point policy matches or beats
    // both on latency.
    let cut_gen = get("cutpoint", generous);
    assert!(get("fixed-row", generous).feasible);
    assert!(get("fixed-frame", generous).feasible);
    assert!(cut_gen.feasible);
    assert!(cut_gen.latency_ms <= get("fixed-row", generous).latency_ms * 1.0001);
    assert!(cut_gen.latency_ms <= get("fixed-frame", generous).latency_ms * 1.0001);

    // Mid budget — the crossover: the frame endpoint no longer fits, the
    // row endpoint still does, and the optimizer lands on a mixed policy
    // that fits the budget and still beats fixed-row.
    let cut_mid = get("cutpoint", mid);
    assert!(!get("fixed-frame", mid).feasible, "mid budget must exclude all-frame");
    assert!(get("fixed-row", mid).feasible);
    assert!(cut_mid.feasible);
    assert!(cut_mid.sram_bytes <= mid);
    assert!(cut_mid.latency_ms <= get("fixed-row", mid).latency_ms * 1.0001);
    // all-frame is the only zero-row-group policy in the cut space, and
    // it no longer fits — the winner must have crossed over to row reuse
    // for at least one block
    assert!(cut_mid.row_groups > 0, "crossover must introduce row-reuse groups");
    // shrinking the budget shrinks the feasible cut space, so the
    // optimized latency can only degrade
    assert!(cut_gen.latency_ms <= cut_mid.latency_ms * 1.0001);

    // Tiny budget: below the minimum-buffer point no *whole-frame*
    // policy fits; the sweep reports that honestly instead of silently
    // recommending an unbuildable design. (The depth-first tile
    // streamer is exempt from this floor by design — shrinking its
    // working set below the eq-1 weight preload is its entire point.)
    for p in exploration
        .points
        .iter()
        .filter(|p| p.cfg.sram_budget == tiny && p.strategy_name() != "tile")
    {
        assert!(!p.feasible, "{} must be infeasible at {} B", p.strategy_name(), tiny);
    }

    // The Pareto front never contains a dominated or infeasible point.
    let front = exploration.pareto_front(MODEL);
    assert!(!front.is_empty());
    for p in &front.points {
        assert!(p.feasible);
        assert!(!front
            .points
            .iter()
            .any(|q| shortcutfusion::explorer::dominates(q, p)));
    }

    // The recommendation is the generous-budget cut-point winner (ties
    // break toward the optimizer), and it round-trips through
    // Compiler::pack into a loadable, self-contained Program.
    let rec = exploration.recommend(MODEL).expect("a feasible point exists");
    assert_eq!(rec.strategy_name(), "cutpoint");
    assert_eq!(rec.cfg.sram_budget, generous);
    let program = rec.pack().unwrap();
    assert_eq!(program.model(), "ResNet18");
    assert_eq!(program.cfg(), &rec.cfg);
    let loaded = Program::from_bytes(&program.to_bytes()).unwrap();
    assert_eq!(loaded.model(), program.model());
    assert_eq!(loaded.stream().words, program.stream().words);
    let policy = loaded.policy();
    assert_eq!(
        policy.iter().filter(|m| **m == ReuseMode::Row).count(),
        rec.row_groups,
        "packed policy must match the explored point"
    );
    assert_eq!(policy.len(), rec.row_groups + rec.frame_groups);
}

#[test]
fn parallel_mixed_strategy_sweep_keeps_stats_and_results_consistent() {
    let session = Session::new();
    let space = SearchSpace::new(AccelConfig::kcu1500_int8())
        .model(MODEL)
        .input_sizes(&[64])
        .sram_budgets(&[2_000_000, 8_000_000])
        .ablation_strategies();

    let first = space.explore(&session, 4).unwrap();
    let n = first.points.len();
    assert_eq!(n, 8);
    let s1 = session.stats();
    assert_eq!(s1.report_misses, n, "every point compiles exactly once");
    assert_eq!(s1.report_hits, 0);
    assert_eq!(s1.analysis_misses, 1, "one shared fusion analysis");
    assert_eq!(s1.analysis_hits, n - 1);

    // Re-exploring the same space on the warm session is pure cache.
    let second = space.explore(&session, 4).unwrap();
    let s2 = session.stats();
    assert_eq!(s2.report_misses, n);
    assert_eq!(s2.report_hits, n);
    assert_eq!(s2.analysis_hits, s1.analysis_hits, "hits only count real compiles");
    for (a, b) in first.points.iter().zip(&second.points) {
        assert_eq!(a.strategy_name(), b.strategy_name());
        assert_eq!(a.cfg.name, b.cfg.name);
        assert_eq!(a.latency_ms, b.latency_ms, "cache hits must be bit-identical");
        assert_eq!(a.dram_bytes, b.dram_bytes);
        assert_eq!(a.sram_bytes, b.sram_bytes);
    }

    // Mixed strategies at the same (model, input, config) stayed
    // distinct points: same budget, different policies/costs recorded.
    let at_big: Vec<_> =
        first.points.iter().filter(|p| p.cfg.sram_budget == 8_000_000).collect();
    assert_eq!(at_big.len(), 4);
    let row = at_big.iter().find(|p| p.strategy_name() == "fixed-row").unwrap();
    let frame = at_big.iter().find(|p| p.strategy_name() == "fixed-frame").unwrap();
    assert_eq!(row.frame_groups, 0);
    assert_eq!(frame.row_groups, 0);
    assert_ne!(row.dram_bytes, frame.dram_bytes);
}
