//! Multi-FPGA sharding acceptance tests.
//!
//! The load-bearing properties of `shard::Partitioner` +
//! `engine::ShardedBackend`:
//! (a) a 2-shard ReferenceBackend chain is **bit-identical** to the
//!     unsharded functional simulator on multiple zoo models;
//! (b) the sharded virtual-timing chain equals the partitioner's
//!     analytical pipeline model within rounding;
//! (c) as link bandwidth grows, the best split's latency is monotone
//!     non-increasing and converges to the pure sum of shard latencies,
//!     and a 1-device plan degenerates byte-identically to
//!     `Compiler::pack`.

use std::sync::Arc;

use shortcutfusion::analyzer::analyze;
use shortcutfusion::compiler::Compiler;
use shortcutfusion::config::AccelConfig;
use shortcutfusion::engine::{
    EngineConfig, ExecutionBackend, InferenceEngine, ReferenceBackend, ShardedBackend,
    VirtualAccelBackend,
};
use shortcutfusion::funcsim::{Params, Tensor};
use shortcutfusion::graph::Graph;
use shortcutfusion::shard::{boundaries, LinkModel, Partitioner, ShardPlan};
use shortcutfusion::testutil::Rng;
use shortcutfusion::zoo;

fn cfg() -> AccelConfig {
    AccelConfig::kcu1500_int8()
}

fn plan_k(graph: &Graph, devices: usize, link: LinkModel) -> ShardPlan {
    Partitioner::homogeneous(cfg(), devices)
        .unwrap()
        .with_link(link)
        .plan(graph)
        .unwrap_or_else(|e| panic!("{}: {e}", graph.name))
}

fn random_input(shape: shortcutfusion::graph::Shape, seed: u64) -> Tensor {
    let mut rng = Rng::from_seed(seed);
    Tensor::from_vec(shape, rng.i8_vec(shape.numel()))
}

/// (a) bit-identical 2-shard reference chain, on two zoo models.
#[test]
fn two_shard_reference_chain_is_bit_identical_to_unsharded_funcsim() {
    for graph in [zoo::tinynet(), zoo::resnet18(64)] {
        let gg = analyze(&graph);
        let params = Params::random(&gg, 11);

        // unsharded ground truth through the same backend API
        let compiler = Compiler::new(cfg()).with_params(params.clone());
        let analyzed = compiler.analyze(&graph).unwrap();
        let lowered = compiler
            .lower(&compiler.allocate(&compiler.optimize(&analyzed).unwrap()).unwrap())
            .unwrap();
        let full = compiler.pack(&lowered).unwrap();
        let input = random_input(full.input_shape(), 3);
        let want = ReferenceBackend.run(&full, &input).unwrap().output.unwrap();

        // 2-shard chain over the same parameters
        let plan = plan_k(&graph, 2, LinkModel::pcie_gen3());
        let programs: Vec<Arc<_>> = plan
            .pack_with_params(Some(&params))
            .unwrap()
            .into_iter()
            .map(Arc::new)
            .collect();
        assert_eq!(programs.len(), 2, "{}", graph.name);
        let chain =
            ShardedBackend::new(programs, Arc::new(ReferenceBackend), LinkModel::pcie_gen3())
                .unwrap();
        let front = chain.front().clone();
        let got = chain.run(&front, &input).unwrap().output.unwrap();

        assert_eq!(got.shape, want.shape, "{}", graph.name);
        assert_eq!(got.data, want.data, "{}: sharded chain diverged", graph.name);
    }
}

/// (b) the virtual-timing chain reproduces the analytical pipeline model.
#[test]
fn sharded_virtual_timing_matches_the_analytical_pipeline_model() {
    for (graph, devices) in [(zoo::tinynet(), 2), (zoo::resnet18(64), 3)] {
        let link = LinkModel::new(4.0, 10.0).unwrap();
        let plan = plan_k(&graph, devices, link);
        let programs: Vec<Arc<_>> =
            plan.pack().unwrap().into_iter().map(Arc::new).collect();
        let chain =
            ShardedBackend::new(programs, Arc::new(VirtualAccelBackend), link).unwrap();
        let front = chain.front().clone();
        let input = Tensor::zeros(front.input_shape());
        let r = chain.run(&front, &input).unwrap();

        let got = r.model_latency_ms.unwrap();
        let tol = 1e-9 * plan.latency_ms.max(1.0);
        assert!(
            (got - plan.latency_ms).abs() <= tol,
            "{} x{devices}: chain {got} ms vs plan {} ms",
            graph.name,
            plan.latency_ms
        );
        // instruction-replay traffic equals the analytical eq-8/9 total,
        // summed across shards
        assert_eq!(r.dram_bytes.unwrap(), plan.total_dram_bytes(), "{}", graph.name);
    }
}

/// The engine serves a sharded model transparently through the chain.
#[test]
fn inference_engine_serves_a_sharded_model() {
    let plan = plan_k(&zoo::tinynet(), 2, LinkModel::pcie_gen3());
    let programs: Vec<Arc<_>> = plan.pack().unwrap().into_iter().map(Arc::new).collect();
    let chain = ShardedBackend::new(
        programs,
        Arc::new(VirtualAccelBackend),
        LinkModel::pcie_gen3(),
    )
    .unwrap();
    let front = chain.front().clone();
    let engine = InferenceEngine::new(
        front.clone(),
        Arc::new(chain),
        EngineConfig { workers: 2, queue_capacity: 16, max_batch: 4, ..EngineConfig::default() },
    );
    let pending: Vec<_> = (0..8)
        .map(|_| engine.submit(Tensor::zeros(front.input_shape())).unwrap())
        .collect();
    for p in pending {
        let done = p.wait().unwrap();
        assert_eq!(done.result.backend, "sharded");
        assert!((done.result.model_latency_ms.unwrap() - plan.latency_ms).abs() < 1e-9);
    }
    let stats = engine.shutdown();
    assert_eq!(stats.completed, 8);
    assert_eq!(stats.failed, 0);
    assert_eq!(stats.backend, "sharded");
}

/// (c) part 1: a 1-device plan degenerates exactly to `Compiler::pack`.
#[test]
fn one_device_plan_packs_byte_identically_to_the_unsharded_compiler() {
    for graph in [zoo::tinynet(), zoo::resnet18(64)] {
        let plan = plan_k(&graph, 1, LinkModel::pcie_gen3());
        let programs = plan.pack().unwrap();
        assert_eq!(programs.len(), 1);
        assert!(programs[0].boundary().is_none(), "{}", graph.name);

        let compiler = Compiler::new(cfg());
        let analyzed = compiler.analyze(&graph).unwrap();
        let lowered = compiler
            .lower(&compiler.allocate(&compiler.optimize(&analyzed).unwrap()).unwrap())
            .unwrap();
        let direct = compiler.pack(&lowered).unwrap();
        assert_eq!(
            programs[0].to_bytes(),
            direct.to_bytes(),
            "{}: K=1 plan must be byte-identical to today's pack",
            graph.name
        );
    }
}

/// (c) part 2: best-split latency is monotone in link bandwidth and
/// converges to the transfer-free sum of shard latencies.
#[test]
fn best_split_latency_lower_bounds_as_link_bandwidth_grows() {
    let graph = zoo::resnet18(64);
    let ladder = [2.0, 8.0, 64.0, 1e6];
    let mut last = f64::INFINITY;
    for gbps in ladder {
        let plan = plan_k(&graph, 2, LinkModel::new(gbps, 0.0).unwrap());
        assert!(
            plan.latency_ms <= last + 1e-12,
            "best-split latency must not grow with bandwidth ({gbps} GB/s: {} vs {last})",
            plan.latency_ms
        );
        last = plan.latency_ms;
    }
    // at (numerically) infinite bandwidth and zero setup latency the
    // transfers vanish: latency is exactly the sum of the two shard
    // latencies, lower-bounded by the slower shard
    let free = plan_k(&graph, 2, LinkModel::new(f64::INFINITY, 0.0).unwrap());
    let sum: f64 = free.shards.iter().map(|s| s.latency_ms).sum();
    assert!((free.latency_ms - sum).abs() <= 1e-9 * sum, "{} vs {sum}", free.latency_ms);
    let slower = free.shards.iter().map(|s| s.latency_ms).fold(0.0f64, f64::max);
    assert!(free.latency_ms >= slower);
    assert_eq!(free.interval_ms, slower, "free links make the slower shard the bottleneck");
    assert!(free.latency_ms <= last + 1e-12, "infinite link is the limit of the ladder");
}

/// Boundary discovery: single-tensor cuts only, heads in the last shard.
#[test]
fn boundary_discovery_is_structurally_sound() {
    // classifiers offer many cuts; every descriptor names a real node
    let g = zoo::resnet18(64);
    let bounds = boundaries(&g).unwrap();
    assert!(bounds.len() >= 4, "{}", bounds.len());
    for b in &bounds {
        let node = g.find(&b.tensor.name).expect("crossing node exists");
        assert_eq!(g.node(node).out_shape, b.tensor.shape);
    }
    // a multi-output detector still offers backbone cuts
    assert!(!boundaries(&zoo::yolov3(256)).unwrap().is_empty());
}

/// Heterogeneous deployments: configs apply in pipeline order, and plan
/// feasibility is exactly the conjunction of per-shard feasibility, each
/// shard judged against its *own* device's budget.
#[test]
fn heterogeneous_configs_apply_in_pipeline_order() {
    let graph = zoo::resnet18(64);
    let mut big = cfg();
    big.name = "big-board".into();
    let mut small = cfg();
    small.name = "small-board".into();
    small.sram_budget = big.sram_budget / 4;
    let plan = Partitioner::heterogeneous(vec![big, small])
        .unwrap()
        .plan(&graph)
        .unwrap();
    assert_eq!(plan.devices(), 2);
    assert_eq!(plan.shards[0].cfg.name, "big-board");
    assert_eq!(plan.shards[1].cfg.name, "small-board");
    assert_eq!(plan.feasible, plan.shards.iter().all(|s| s.feasible));
    for s in &plan.shards {
        if s.feasible {
            assert!(s.sram_bytes <= s.cfg.sram_budget, "shard {}", s.index);
        }
    }
    // packed artifacts embed their own device's config
    let programs = plan.pack().unwrap();
    assert_eq!(programs[0].cfg().name, "big-board");
    assert_eq!(programs[1].cfg().name, "small-board");
}
