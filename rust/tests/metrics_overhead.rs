//! Allocation-freedom of the always-on telemetry hot path.
//!
//! The serving engine records queue-wait, batch-size and cold-load
//! samples on **every** request with metrics that cannot be switched
//! off, and consults the trace sink's `enabled()` gate before building
//! any event. That is only acceptable if the per-event cost is a few
//! atomic adds: this suite installs a counting global allocator and
//! asserts that recording into [`Counter`] / [`Histogram`] and hitting
//! the disabled [`NullSink`] gate allocate **zero** bytes.
//!
//! (Test binaries get their own process, so the global allocator here
//! cannot interfere with the rest of the suite.)

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use shortcutfusion::telemetry::{Counter, Histogram, NullSink, TraceSink, MS_BOUNDS};

/// System allocator wrapper that counts allocation calls.
struct CountingAlloc;

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Allocation calls observed while running `f`.
fn allocations_during(f: impl FnOnce()) -> u64 {
    let before = ALLOCATIONS.load(Ordering::SeqCst);
    f();
    ALLOCATIONS.load(Ordering::SeqCst) - before
}

// One test function on purpose: concurrent tests in the same binary
// would allocate on other threads mid-measurement and fail spuriously.
#[test]
fn record_path_never_allocates() {
    // construction allocates (bucket vectors) — done before measuring
    let counter = Counter::new();
    let hist = Histogram::new(MS_BOUNDS);
    let sink = NullSink;

    let n = allocations_during(|| {
        for i in 0..10_000u64 {
            counter.inc();
            counter.add(3);
            // samples spanning the first bucket, every edge, and overflow
            hist.record(i as f64 * 0.01);
            // the engine's hot-path gate for a detached trace sink
            assert!(!sink.enabled());
        }
    });
    assert_eq!(n, 0, "metrics record path must be allocation-free, saw {n} allocations");
    assert_eq!(counter.get(), 40_000);

    // snapshots are allowed to allocate (they clone the bucket counts) —
    // the contract is only about the record path, which must stay
    // allocation-free afterwards too
    let snap = hist.snapshot();
    assert_eq!(snap.count, 10_000);
    let n = allocations_during(|| hist.record(2.0));
    assert_eq!(n, 0, "recording after a snapshot must stay allocation-free");
}
