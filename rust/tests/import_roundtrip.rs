//! ONNX front-end round-trip tests.
//!
//! The fixture strategy is hermetic: every `.onnx` byte string is
//! produced in-process by the exporter (`import::export_bytes`), so the
//! repo carries no binary blobs and the importer is tested against
//! exactly the opset the compiler can represent:
//!
//! * every zoo model round-trips export→import **structurally**
//!   (node-for-node names, ops, wiring, shapes) and **bit-identically**
//!   through the functional simulator (every intermediate tensor, not
//!   just the final output);
//! * corrupted buffers (truncation, bad tags, inconsistent initializer
//!   shapes, unsupported ops) are typed [`ImportError`]s, never panics;
//! * an imported model packs into a [`Program`] the [`InferenceEngine`]
//!   serves over both the plain `ReferenceBackend` and a `PooledBackend`,
//!   bit-identical to the hand-built graph (the acceptance path).

use std::sync::Arc;

use shortcutfusion::analyzer::analyze;
use shortcutfusion::compiler::{CompileError, Compiler};
use shortcutfusion::config::AccelConfig;
use shortcutfusion::engine::{
    EngineConfig, ExecutionBackend, InferenceEngine, ReferenceBackend,
};
use shortcutfusion::funcsim::{Executor, Params, Tensor};
use shortcutfusion::graph::Graph;
use shortcutfusion::import::{export_bytes, import_model, ImportError};
use shortcutfusion::pool::{policy_by_name, BufferPool, PoolConfig, PooledBackend};
use shortcutfusion::program::Program;
use shortcutfusion::testutil::Rng;
use shortcutfusion::zoo;

/// Small build resolution per model: large enough for every stride /
/// upsample chain to stay consistent (powers of two), small enough that
/// debug-mode funcsim stays fast. `tinynet` ignores it (fixed geometry).
fn test_input(name: &str) -> usize {
    match name {
        "retinanet" | "efficientdet-d0" => 64,
        _ => 32,
    }
}

fn assert_same_structure(name: &str, built: &Graph, imported: &Graph) {
    assert_eq!(imported.name, built.name, "{name}: graph name");
    assert_eq!(imported.nodes.len(), built.nodes.len(), "{name}: node count");
    for (a, b) in built.nodes.iter().zip(&imported.nodes) {
        assert_eq!(b.name, a.name, "{name}: node order/name");
        assert_eq!(b.op, a.op, "{name}: op of {}", a.name);
        assert_eq!(b.inputs, a.inputs, "{name}: wiring of {}", a.name);
        assert_eq!(b.out_shape, a.out_shape, "{name}: shape of {}", a.name);
    }
}

#[test]
fn every_zoo_model_round_trips_structurally() {
    for &name in zoo::KNOWN_NAMES {
        let g = zoo::by_name(name, test_input(name)).unwrap();
        let bytes = export_bytes(&g, None).unwrap_or_else(|e| panic!("{name}: export: {e}"));
        let imp = import_model(&bytes).unwrap_or_else(|e| panic!("{name}: import: {e}"));
        assert_same_structure(name, &g, &imp.graph);
        // a paramless export still carries zero-filled weight tensors
        // (valid ONNX needs them) — none may come back non-zero
        for (gname, gp) in &imp.params.groups {
            assert!(
                gp.weights.iter().all(|&w| w == 0),
                "{name}: {gname} invented weights"
            );
        }
    }
}

#[test]
fn every_zoo_model_round_trips_bit_identically_through_funcsim() {
    for &name in zoo::KNOWN_NAMES {
        let g = zoo::by_name(name, test_input(name)).unwrap();
        let gg = analyze(&g);
        let params = Params::random(&gg, 7);
        let bytes =
            export_bytes(&g, Some(&params)).unwrap_or_else(|e| panic!("{name}: export: {e}"));
        let imp = import_model(&bytes).unwrap_or_else(|e| panic!("{name}: import: {e}"));
        assert_same_structure(name, &g, &imp.graph);
        let igg = analyze(&imp.graph);

        let shape = g.input().out_shape;
        let mut rng = Rng::from_seed(5);
        let input = Tensor::from_vec(shape, rng.i8_vec(shape.numel()));
        let want = Executor::new(&gg, &params).run(&input).unwrap();
        let got = Executor::new(&igg, &imp.params)
            .run(&input)
            .unwrap_or_else(|e| panic!("{name}: imported exec: {e}"));
        // per-node values: every intermediate tensor must match, not
        // just the network output
        assert_eq!(want.len(), got.len(), "{name}");
        for (i, (w, g2)) in want.iter().zip(&got).enumerate() {
            assert_eq!(w, g2, "{name}: tensor of node {} diverged", gg.graph.nodes[i].name);
        }
    }
}

#[test]
fn truncated_and_corrupted_buffers_are_typed_errors_never_panics() {
    // a varint that promises more bytes than the buffer has
    let e = import_model(&[0x08, 0xFF]).unwrap_err();
    assert!(matches!(e, ImportError::Wire { .. }), "{e}");
    // field number 0 is reserved
    let e = import_model(&[0x00, 0x01]).unwrap_err();
    assert!(matches!(e, ImportError::Wire { .. }), "{e}");
    // wire type 3 (group) is not used by ONNX and is rejected
    let e = import_model(&[0x0B]).unwrap_err();
    assert!(matches!(e, ImportError::Wire { .. }), "{e}");
    // a length-delimited field running past the end of the buffer
    let e = import_model(&[0x3A, 0x7F, 0x01]).unwrap_err();
    assert!(matches!(e, ImportError::Wire { .. }), "{e}");
    // an empty buffer decodes to a ModelProto with no graph: Schema
    let e = import_model(&[]).unwrap_err();
    assert!(matches!(e, ImportError::Schema(_)), "{e}");

    // every prefix of a real model must fail cleanly (or, for a few
    // lucky cut points, decode) — never panic
    let g = zoo::by_name("tinynet", 16).unwrap();
    let params = Params::random(&analyze(&g), 7);
    let bytes = export_bytes(&g, Some(&params)).unwrap();
    for len in 0..bytes.len() {
        let _ = import_model(&bytes[..len]);
    }
}

#[test]
fn inconsistent_initializer_shapes_are_shape_mismatch() {
    use shortcutfusion::import::proto::{encode_model, GraphProto, ModelProto, TensorProto};

    // hand-assemble a model whose initializer claims dims [2,2] but
    // carries 3 values
    let model = ModelProto {
        ir_version: 8,
        opset_version: 14,
        graph: Some(GraphProto {
            name: "bad".into(),
            initializer: vec![TensorProto::f32s("w", vec![2, 2], vec![1.0, 2.0, 3.0])],
            ..GraphProto::default()
        }),
        ..ModelProto::default()
    };
    let e = import_model(&encode_model(&model)).unwrap_err();
    assert!(matches!(e, ImportError::ShapeMismatch { .. }), "{e}");
}

#[test]
fn unsupported_ops_are_typed_with_the_node_name() {
    use shortcutfusion::import::proto::{decode_model, encode_model};

    // exporting a real graph, then renaming one op to something the
    // lowering table does not cover, must produce UnsupportedOp
    let g = zoo::by_name("tinynet", 16).unwrap();
    let bytes = export_bytes(&g, None).unwrap();
    let mut model = decode_model(&bytes).unwrap();
    let graph = model.graph.as_mut().unwrap();
    let node = graph.node.iter_mut().find(|n| n.op_type == "Conv").unwrap();
    node.op_type = "ConvTranspose".into();
    match import_model(&encode_model(&model)).unwrap_err() {
        ImportError::UnsupportedOp { op_type, .. } => assert_eq!(op_type, "ConvTranspose"),
        other => panic!("expected UnsupportedOp, got {other}"),
    }
}

/// The acceptance path: an imported model packs into a `Program` that the
/// `InferenceEngine` serves — bit-identical to the hand-built graph —
/// over the plain reference backend and again through a `PooledBackend`.
#[test]
fn imported_model_packs_and_serves_bit_identically_including_pooled() {
    let g = zoo::by_name("tinynet", 16).unwrap();
    let params = Params::random(&analyze(&g), 7);
    let bytes = export_bytes(&g, Some(&params)).unwrap();
    let imp = import_model(&bytes).unwrap();

    let pack = |graph: &Graph, params: Params| -> Arc<Program> {
        let mut compiler = Compiler::new(AccelConfig::kcu1500_int8());
        let analyzed = compiler.analyze(graph).unwrap();
        compiler = compiler.with_params(params);
        let lowered = compiler
            .lower(&compiler.allocate(&compiler.optimize(&analyzed).unwrap()).unwrap())
            .unwrap();
        // round-trip through bytes so the loaded-artifact path is covered
        Arc::new(Program::from_bytes(&compiler.pack(&lowered).unwrap().to_bytes()).unwrap())
    };
    let built = pack(&g, params);
    let imported = pack(&imp.graph, imp.params);
    assert_eq!(imported.model(), built.model());
    assert_eq!(imported.input_shape(), built.input_shape());

    let shape = built.input_shape();
    let mut rng = Rng::from_seed(9);
    let inputs: Vec<Tensor> =
        (0..4).map(|_| Tensor::from_vec(shape, rng.i8_vec(shape.numel()))).collect();
    let expect: Vec<_> = inputs
        .iter()
        .map(|i| ReferenceBackend.run(&built, i).unwrap().output.unwrap())
        .collect();

    // plain reference backend through the engine
    let engine = InferenceEngine::new(
        imported.clone(),
        Arc::new(ReferenceBackend),
        EngineConfig { workers: 2, queue_capacity: 8, max_batch: 2, ..EngineConfig::default() },
    );
    let pending: Vec<_> = inputs.iter().map(|i| engine.submit(i.clone()).unwrap()).collect();
    for (p, want) in pending.into_iter().zip(&expect) {
        let done = p.wait().unwrap();
        assert_eq!(done.result.output.as_ref(), Some(want));
    }
    engine.shutdown();

    // again through a buffer pool large enough to hold the weights
    let pool = Arc::new(
        BufferPool::new(
            PoolConfig::new(imported.resident_bytes().max(1) * 2),
            policy_by_name("lru").unwrap(),
        )
        .unwrap(),
    );
    let pooled = Arc::new(PooledBackend::new(
        Arc::new(ReferenceBackend),
        pool,
        imported.model(),
    ));
    let engine = InferenceEngine::new(
        imported,
        pooled,
        EngineConfig { workers: 2, queue_capacity: 8, max_batch: 2, ..EngineConfig::default() },
    );
    let pending: Vec<_> = inputs.iter().map(|i| engine.submit(i.clone()).unwrap()).collect();
    for (p, want) in pending.into_iter().zip(&expect) {
        let done = p.wait().unwrap();
        assert_eq!(done.result.output.as_ref(), Some(want));
    }
    engine.shutdown();
}

#[test]
fn import_errors_convert_into_the_compile_error_taxonomy() {
    let wire: CompileError = ImportError::wire(3, "boom").into();
    assert!(matches!(wire, CompileError::Parse(_)));
    let unsup: CompileError =
        ImportError::unsupported("Softmax", "probs", "not in the datapath").into();
    assert!(matches!(unsup, CompileError::Unsupported(_)));
    let shape: CompileError = ImportError::shape("c1", "bad dims").into();
    assert!(matches!(shape, CompileError::Graph(_)));
}
