//! Virtual-clock scenario tests for the continuous-batching scheduler.
//!
//! These tests pin the event-driven serving semantics deterministically —
//! no sleeps, no wall-clock racing:
//!
//! * a request arriving mid-batch joins the in-flight batch at the next
//!   execution boundary under `BatchPolicy::Continuous` and waits for the
//!   next full window under `BatchPolicy::Window` (proved both at the
//!   threaded-engine level with a channel-gated backend, and in pure
//!   virtual time against a pipelined device model);
//! * deadline expiry surfaces as `EngineStats::deadline_misses` and a
//!   typed `CompileError::DeadlineMiss` on the waiting handle;
//! * admission control rejects at the configured depth with a typed
//!   `CompileError::Rejected` carrying the observed load and a
//!   retry-after hint, and backend-reported load (the
//!   `queue_depth_hint`) tightens admission before the queue fills;
//! * draining on shutdown loses no accepted request;
//! * a single-request workload is bit-for-bit identical under the
//!   windowed and continuous policies.

use std::collections::{HashMap, VecDeque};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

use shortcutfusion::compiler::CompileError;
use shortcutfusion::engine::{
    BatchPolicy, EngineConfig, EngineStats, ExecutionBackend, InferenceEngine,
    ReferenceBackend, RunResult, Scheduler, SchedulerConfig, Ticket, VirtualAccelBackend,
    VirtualClock,
};
use shortcutfusion::funcsim::Tensor;
use shortcutfusion::program::Program;
use shortcutfusion::testutil::Rng;
use shortcutfusion::zoo;

fn tinynet_program() -> Arc<Program> {
    Arc::new(shortcutfusion::testutil::pack_program(&zoo::tinynet(), None))
}

const STEP_TIMEOUT: Duration = Duration::from_secs(30);

/// Test backend driven one request at a time over channels: `entered`
/// fires when a request starts executing, and the request finishes only
/// when the test sends on `release`. This makes batch-formation order
/// fully deterministic — the test knows exactly when the worker sits at
/// an execution boundary.
struct StepBackend {
    entered: mpsc::Sender<()>,
    release: Mutex<mpsc::Receiver<()>>,
}

impl ExecutionBackend for StepBackend {
    fn name(&self) -> &'static str {
        "step"
    }

    fn run(&self, _program: &Program, _input: &Tensor) -> shortcutfusion::Result<RunResult> {
        self.entered.send(()).expect("test dropped the entered channel");
        self.release
            .lock()
            .unwrap()
            .recv_timeout(STEP_TIMEOUT)
            .expect("test never released the request");
        Ok(RunResult {
            backend: "step",
            output: None,
            model_latency_ms: Some(1.0),
            dram_bytes: None,
            cold_load_ms: None,
            traffic_classes: None,
        })
    }
}

/// One worker, max_batch 2: submit r1, wait until it is *executing* (its
/// batch was claimed with r1 alone), submit r2 mid-batch, then release
/// both. Under Continuous r2 must join r1's still-open batch at the
/// execution boundary; under Window it must wait for a second window.
fn mid_batch_arrival(policy: BatchPolicy) -> EngineStats {
    let program = tinynet_program();
    let shape = program.input_shape();
    let (entered_tx, entered_rx) = mpsc::channel();
    let (release_tx, release_rx) = mpsc::channel();
    let engine = InferenceEngine::new(
        program,
        Arc::new(StepBackend { entered: entered_tx, release: Mutex::new(release_rx) }),
        EngineConfig {
            workers: 1,
            queue_capacity: 8,
            max_batch: 2,
            policy,
            deadline_ms: None,
        },
    );
    let p1 = engine.submit(Tensor::zeros(shape)).unwrap();
    entered_rx.recv_timeout(STEP_TIMEOUT).expect("r1 never started");
    let p2 = engine.submit(Tensor::zeros(shape)).unwrap(); // arrives mid-batch
    release_tx.send(()).unwrap(); // r1 finishes -> execution boundary
    entered_rx.recv_timeout(STEP_TIMEOUT).expect("r2 never started");
    release_tx.send(()).unwrap();
    p1.wait().unwrap();
    p2.wait().unwrap();
    engine.shutdown()
}

#[test]
fn continuous_joins_the_open_batch_where_window_waits() {
    let c = mid_batch_arrival(BatchPolicy::Continuous);
    assert_eq!(c.completed, 2);
    assert_eq!(c.batches, 1, "continuous: r2 must extend r1's batch, not open a new one");
    assert_eq!(c.joined, 1, "continuous: r2 must be counted as a mid-batch join");

    let w = mid_batch_arrival(BatchPolicy::Window);
    assert_eq!(w.completed, 2);
    assert_eq!(w.batches, 2, "window: r2 must wait for the next batch window");
    assert_eq!(w.joined, 0, "window: the open batch never admits arrivals");
}

/// Drive the bare `Scheduler` against a pipelined virtual device in pure
/// virtual time: one group-boundary tick per millisecond, the device
/// ingests one request per tick, and a request entering the pipeline at
/// tick `t` completes at `t + groups`. Returns per-client completion
/// times plus the scheduler counters.
fn pipelined_completion_times(
    policy: BatchPolicy,
    arrivals: &[(f64, u64)], // (arrival time ms, client)
    groups: u64,
) -> (HashMap<u64, f64>, shortcutfusion::engine::SchedCounters) {
    let mut sched = Scheduler::new(
        SchedulerConfig { policy, max_batch: 4, queue_capacity: 16, deadline_ms: None },
        1,
    );
    let mut claimed: VecDeque<Ticket> = VecDeque::new(); // dispatched, not yet in the pipe
    let mut running: Vec<(Ticket, f64)> = Vec::new(); // in the pipe, with finish time
    let mut done: HashMap<u64, f64> = HashMap::new();
    let mut submitted = 0;
    let mut now = 0.0;
    while done.len() < arrivals.len() {
        assert!(now < 1e4, "virtual-device simulation did not converge");
        while submitted < arrivals.len() && arrivals[submitted].0 <= now {
            sched.submit(arrivals[submitted].1, now, None, 0).unwrap();
            submitted += 1;
        }
        // completions land before this tick's dispatch decisions
        running.retain(|(ticket, finish)| {
            if *finish <= now {
                sched.complete(0, ticket.id, *finish);
                done.insert(ticket.client, *finish);
                false
            } else {
                true
            }
        });
        // batch formation: claim when idle; every tick is a group
        // boundary, so the continuous policy also joins here
        claimed.extend(sched.claim(0, now));
        claimed.extend(sched.join(0, now));
        // the pipeline ingests one request per boundary tick
        if let Some(ticket) = claimed.pop_front() {
            let finish = now + groups as f64;
            running.push((ticket, finish));
        }
        now += 1.0;
    }
    (done, sched.counters())
}

#[test]
fn mid_batch_arrival_is_served_without_waiting_for_the_next_window() {
    // r1 arrives at t=0 and occupies the device for 4 group ticks;
    // r2 arrives at t=1, mid-batch
    let arrivals = [(0.0, 1), (1.0, 2)];
    let (cont, cc) = pipelined_completion_times(BatchPolicy::Continuous, &arrivals, 4);
    let (win, wc) = pipelined_completion_times(BatchPolicy::Window, &arrivals, 4);

    // r1 is unaffected by the policy
    assert_eq!(cont[&1], 4.0);
    assert_eq!(win[&1], 4.0);
    // window: r2 waits for r1's window to drain (enters at t=4)
    assert_eq!(win[&2], 8.0);
    // continuous: r2 joins the open batch and enters the pipeline at the
    // very next group boundary (t=1), completing a full window earlier
    assert_eq!(cont[&2], 5.0);
    assert!(
        cont[&2] < win[&2],
        "continuous must serve the mid-batch arrival strictly earlier"
    );

    assert_eq!((cc.batches, cc.joined), (1, 1));
    assert_eq!((wc.batches, wc.joined), (2, 0));
}

#[test]
fn deadline_expiry_increments_misses_and_surfaces_typed() {
    let program = tinynet_program();
    let clock = Arc::new(VirtualClock::new());
    // paused engine: the queued request can only expire, never execute
    let engine = InferenceEngine::new_paused_with_clock(
        program.clone(),
        Arc::new(VirtualAccelBackend),
        EngineConfig { deadline_ms: Some(8.0), ..EngineConfig::default() },
        clock.clone(),
    );
    let p = engine.submit(Tensor::zeros(program.input_shape())).unwrap();
    assert_eq!(engine.stats().deadline_misses, 0, "nothing expired at t=0");
    clock.advance_ms(20.0);
    let stats = engine.stats();
    assert_eq!(stats.deadline_misses, 1);
    assert_eq!(stats.queue_depth, 0, "the expired request must leave the queue");
    assert_eq!(stats.submitted, 1);
    assert_eq!(stats.completed, 0);
    match p.wait() {
        Err(CompileError::DeadlineMiss { deadline_ms, now_ms }) => {
            assert_eq!(deadline_ms, 8.0);
            assert_eq!(now_ms, 20.0);
        }
        other => panic!("expected a typed deadline miss, got {other:?}"),
    }
}

#[test]
fn backpressure_rejects_at_the_configured_depth() {
    let program = tinynet_program();
    let clock = Arc::new(VirtualClock::new());
    let mut engine = InferenceEngine::new_paused_with_clock(
        program.clone(),
        Arc::new(VirtualAccelBackend),
        EngineConfig {
            workers: 1,
            queue_capacity: 3,
            max_batch: 1,
            policy: BatchPolicy::Continuous,
            deadline_ms: Some(50.0),
        },
        clock,
    );
    let shape = program.input_shape();
    let accepted: Vec<_> =
        (0..3).map(|_| engine.try_submit(Tensor::zeros(shape)).unwrap()).collect();
    match engine.try_submit(Tensor::zeros(shape)) {
        Err(CompileError::Rejected { depth, deadline_ms }) => {
            assert_eq!(depth, 3, "rejection must report the observed load");
            // retry-after hint: the earliest queued deadline (all three
            // were accepted at virtual t=0 with the 50 ms default)
            assert_eq!(deadline_ms, Some(50.0));
        }
        other => panic!("expected typed backpressure, got {other:?}"),
    }
    assert_eq!(engine.stats().rejected, 1);
    assert_eq!(engine.stats().submitted, 3, "rejected requests never count as submitted");
    engine.start();
    for p in accepted {
        p.wait().unwrap();
    }
    let stats = engine.shutdown();
    assert_eq!(stats.completed, 3);
    assert_eq!(stats.deadline_misses, 0, "the real clock stayed well inside 50 ms");
}

/// Backend that pretends to hold a deep private queue (e.g. a buffer
/// pool with many cold fills in flight).
struct BusyBackend;

impl ExecutionBackend for BusyBackend {
    fn name(&self) -> &'static str {
        "busy"
    }

    fn run(&self, _program: &Program, _input: &Tensor) -> shortcutfusion::Result<RunResult> {
        Ok(RunResult {
            backend: "busy",
            output: None,
            model_latency_ms: Some(1.0),
            dram_bytes: None,
            cold_load_ms: None,
            traffic_classes: None,
        })
    }

    fn queue_depth_hint(&self) -> usize {
        100
    }
}

#[test]
fn backend_load_hint_tightens_admission_before_the_queue_fills() {
    let program = tinynet_program();
    let engine = InferenceEngine::new_paused(
        program.clone(),
        Arc::new(BusyBackend),
        EngineConfig { queue_capacity: 8, ..EngineConfig::default() },
    );
    // the engine's own queue is empty, but the backend reports 100
    // pending units of work — far past the capacity of 8
    match engine.try_submit(Tensor::zeros(program.input_shape())) {
        Err(CompileError::Rejected { depth, .. }) => {
            assert_eq!(depth, 100, "depth must include the backend-reported load");
        }
        other => panic!("expected backpressure from the load hint, got {other:?}"),
    }
    assert_eq!(engine.queue_depth(), 0);
}

#[test]
fn shutdown_drains_every_accepted_request() {
    let program = tinynet_program();
    let shape = program.input_shape();
    let mut engine = InferenceEngine::new_paused(
        program,
        Arc::new(VirtualAccelBackend),
        EngineConfig {
            workers: 3,
            queue_capacity: 32,
            max_batch: 2,
            ..EngineConfig::default()
        },
    );
    let pending: Vec<_> =
        (0..17).map(|_| engine.submit(Tensor::zeros(shape)).unwrap()).collect();
    engine.start();
    let stats = engine.shutdown();
    assert_eq!(stats.completed, 17, "shutdown must drain, not drop");
    assert_eq!(stats.queue_depth, 0);
    assert_eq!(stats.in_flight, 0);
    for p in pending {
        assert!(p.wait().is_ok(), "an accepted request was lost in the drain");
    }
}

#[test]
fn window_and_continuous_are_bitwise_equivalent_on_a_single_request() {
    // packed parameters so the reference backend computes real tensors
    let program =
        Arc::new(shortcutfusion::testutil::pack_program(&zoo::tinynet(), Some(7)));
    let shape = program.input_shape();
    let input = Tensor::from_vec(shape, Rng::from_seed(5).i8_vec(shape.numel()));
    let serve = |policy: BatchPolicy| {
        let engine = InferenceEngine::new(
            program.clone(),
            Arc::new(ReferenceBackend),
            EngineConfig {
                workers: 1,
                queue_capacity: 4,
                max_batch: 1,
                policy,
                deadline_ms: None,
            },
        );
        let done = engine.submit(input.clone()).unwrap().wait().unwrap();
        (done, engine.shutdown())
    };
    let (c, cs) = serve(BatchPolicy::Continuous);
    let (w, ws) = serve(BatchPolicy::Window);
    assert_eq!(c.result, w.result, "policies must produce bit-identical RunResults");
    assert!(c.result.output.is_some(), "the reference backend must compute a tensor");
    assert!(!c.deadline_missed && !w.deadline_missed);
    assert_eq!((cs.completed, ws.completed), (1, 1));
    assert_eq!((cs.failed, ws.failed), (0, 0));
    assert_eq!((cs.deadline_misses, ws.deadline_misses), (0, 0));
    // a lone request can never join an in-flight batch under either policy
    assert_eq!((cs.joined, ws.joined), (0, 0));
}
