//! The staged-API contract tests:
//!
//! 1. the deprecated `compile_model` wrapper is bit-identical to driving
//!    the stages by hand, for every zoo model;
//! 2. `Session` cache hits return byte-identical instruction streams
//!    (property-tested over random job orders and thread counts);
//! 3. baseline strategies produce well-formed reports through the same
//!    pipeline.

use std::sync::Arc;

use shortcutfusion::compiler::{
    CompileError, Compiler, FixedReuseStrategy, Session, ShortcutMiningStrategy,
    SmartShuttleStrategy, SweepJob,
};
use shortcutfusion::config::AccelConfig;
use shortcutfusion::isa::ReuseMode;
use shortcutfusion::testutil::forall;
use shortcutfusion::zoo;

#[test]
#[allow(deprecated)]
fn wrapper_is_equivalent_to_staged_api_for_all_models() {
    let cfg = AccelConfig::kcu1500_int8();
    for &name in zoo::MODEL_NAMES {
        let g = zoo::by_name(name, zoo::default_input(name)).unwrap();

        // old one-shot entry point
        let old = shortcutfusion::coordinator::compile_model(&g, &cfg);

        // the staged pipeline, driven stage by stage
        let compiler = Compiler::new(cfg.clone());
        let analyzed = compiler.analyze(&g).unwrap();
        let optimized = compiler.optimize(&analyzed).unwrap();
        let allocated = compiler.allocate(&optimized).unwrap();
        let lowered = compiler.lower(&allocated).unwrap();
        let new = compiler.simulate(&lowered).unwrap().into_report();

        assert_eq!(old.model, new.model, "{name}");
        assert_eq!(old.evaluation.cuts.cuts, new.evaluation.cuts.cuts, "{name}");
        assert_eq!(old.evaluation.policy, new.evaluation.policy, "{name}");
        assert_eq!(old.evaluation.sram.total, new.evaluation.sram.total, "{name}");
        assert_eq!(old.evaluation.dram.total, new.evaluation.dram.total, "{name}");
        assert_eq!(old.timing.total_cycles, new.timing.total_cycles, "{name}");
        assert_eq!(old.stream.words, new.stream.words, "{name}: streams must be bit-identical");
        assert_eq!(old.row_groups, new.row_groups, "{name}");
        assert_eq!(old.frame_groups, new.frame_groups, "{name}");
        assert!((old.power.total_w - new.power.total_w).abs() < 1e-12, "{name}");
    }
}

#[test]
fn session_cache_hits_return_byte_identical_streams() {
    // Property: for random (model, input, config) jobs in random order
    // with random thread counts, every repeat compile of the same key
    // yields the same Arc (pointer-equal) and, byte-compared anyway, the
    // identical packed instruction stream.
    let models = ["resnet18", "vgg16-conv", "yolov2", "efficientnet-b0"];
    forall("session cache hits are byte-identical", 8, |rng| {
        let session = Session::new();
        let mut cfg_a = AccelConfig::kcu1500_int8();
        cfg_a.sram_budget = 6_000_000 + rng.below(4) * 1_000_000;
        let cfg_b = AccelConfig::kcu1500_int8();
        let cfgs = [cfg_a, cfg_b];

        // a job list with deliberate duplicates
        let mut jobs = Vec::new();
        for _ in 0..rng.range(4, 10) {
            let m = *rng.choose(&models);
            let input = [64usize, 96][rng.below(2)];
            let cfg = cfgs[rng.below(2)].clone();
            jobs.push(SweepJob { model: m.to_string(), input, cfg });
        }
        let threads = rng.range(1, 4);
        let first = session.run_jobs(&jobs, threads);
        let second = session.run_jobs(&jobs, threads);
        for (i, (a, b)) in first.iter().zip(&second).enumerate() {
            let (a, b) = (a.as_ref().unwrap(), b.as_ref().unwrap());
            assert!(Arc::ptr_eq(a, b), "job {i}: rerun must hit the cache");
            let bytes_a: Vec<u8> =
                a.stream.words.iter().flat_map(|w| w.to_le_bytes()).collect();
            let bytes_b: Vec<u8> =
                b.stream.words.iter().flat_map(|w| w.to_le_bytes()).collect();
            assert_eq!(bytes_a, bytes_b, "job {i}: streams must be byte-identical");
        }
        let stats = session.stats();
        assert_eq!(
            stats.report_hits + stats.report_misses,
            2 * jobs.len(),
            "every job is either a hit or a miss"
        );
        assert!(stats.report_hits >= jobs.len(), "second pass must be all hits");
    });
}

#[test]
fn session_parallel_sweep_matches_fresh_compiles() {
    let cfg = AccelConfig::kcu1500_int8();
    let session = Session::new();
    let names = ["resnet18", "yolov2"];
    let results = session.sweep_grid(&names, std::slice::from_ref(&cfg), 4);
    for (&name, r) in names.iter().zip(results) {
        let r = r.unwrap();
        let direct = Compiler::new(cfg.clone())
            .compile(&zoo::by_name(name, zoo::default_input(name)).unwrap())
            .unwrap();
        assert_eq!(direct.model, r.model);
        assert_eq!(direct.stream.words, r.stream.words);
        assert_eq!(direct.timing.total_cycles, r.timing.total_cycles);
    }
}

#[test]
fn baseline_strategies_flow_through_the_same_pipeline() {
    let cfg = AccelConfig::kcu1500_int8();
    let g = zoo::resnet50(224);
    for strategy in [
        Arc::new(FixedReuseStrategy(ReuseMode::Row))
            as Arc<dyn shortcutfusion::compiler::ReuseStrategy>,
        Arc::new(FixedReuseStrategy(ReuseMode::Frame)),
        Arc::new(ShortcutMiningStrategy),
        Arc::new(SmartShuttleStrategy::default()),
    ] {
        let name = strategy.name();
        let r = Compiler::with_strategy(cfg.clone(), strategy).compile(&g).unwrap();
        assert_eq!(r.strategy, name);
        assert_eq!(r.stream.len(), r.grouped.groups.len(), "{name}");
        assert!(r.latency_ms() > 0.0, "{name}");
        assert!(r.evaluation.dram.total > 0, "{name}");
    }
    // ordering sanity: the cut-point optimum never loses to the fixed
    // ablations on DRAM-bound yolov2
    let gy = zoo::yolov2(416);
    let best = Compiler::new(cfg.clone()).compile(&gy).unwrap();
    let row = Compiler::with_strategy(cfg.clone(), Arc::new(FixedReuseStrategy(ReuseMode::Row)))
        .compile(&gy)
        .unwrap();
    assert!(best.latency_ms() <= row.latency_ms() * 1.0001);
}

#[test]
fn unknown_models_and_infeasible_configs_are_typed() {
    let session = Session::new();
    match session.compile("alexnet", 224, &AccelConfig::kcu1500_int8()) {
        Err(CompileError::UnknownModel { name, valid }) => {
            assert_eq!(name, "alexnet");
            assert!(valid.contains(&"resnet18"));
        }
        other => panic!("expected UnknownModel, got {:?}", other.map(|r| r.model.clone())),
    }
    let mut tiny = AccelConfig::kcu1500_int8();
    tiny.sram_budget = 1;
    let strict = Compiler::new(tiny).strict_feasibility(true);
    assert!(matches!(
        strict.compile(&zoo::resnet18(64)),
        Err(CompileError::Infeasible { .. })
    ));
}
