//! Property-based invariants over randomized graphs and policies
//! (in-repo `testutil::prop` driver — proptest is unavailable offline).

use std::collections::{HashMap, VecDeque};

use shortcutfusion::alloc::{allocate, Loc};
use shortcutfusion::analyzer::{analyze, GroupKind};
use shortcutfusion::config::AccelConfig;
use shortcutfusion::graph::{validate, Activation, Graph, GraphBuilder, PadMode, Shape};
use shortcutfusion::engine::{BatchPolicy, Scheduler, SchedulerConfig, Ticket};
use shortcutfusion::isa::ReuseMode;
use shortcutfusion::optimizer::{basic_blocks, dram_access, segments, Optimizer};
use shortcutfusion::sim::simulate;
use shortcutfusion::testutil::{forall, Rng};

/// Generate a random but well-formed CNN: alternating conv stages with
/// optional residual blocks, SE blocks, pools and a classifier.
fn random_cnn(rng: &mut Rng) -> Graph {
    let size = *rng.choose(&[32usize, 48, 64]);
    let mut b = GraphBuilder::new("rand", Shape::new(size, size, 3));
    let mut x = b.input_id();
    let mut c = *rng.choose(&[8usize, 16]);
    x = b.conv_bn_act("stem", x, 3, 1, c, Activation::Relu);
    let stages = rng.range(1, 3);
    let mut id = 0usize;
    for s in 0..stages {
        let blocks = rng.range(1, 3);
        for _ in 0..blocks {
            id += 1;
            if rng.coin() {
                // residual block
                let base = format!("res{id}");
                let c1 = b.conv_bn_act(&format!("{base}/a"), x, 3, 1, c, Activation::Relu);
                let c2 = b.conv(&format!("{base}/b"), c1, 3, 1, c, PadMode::Same);
                let bn = b.batchnorm(&format!("{base}/b/bn"), c2);
                let add = b.add(&format!("{base}/add"), bn, x);
                x = b.activation(&format!("{base}/relu"), add, Activation::Relu);
            } else if rng.coin() {
                // SE block on a fresh conv
                let base = format!("se{id}");
                let cv = b.conv_bn_act(&format!("{base}/conv"), x, 3, 1, c, Activation::Swish);
                let g = b.gap(&format!("{base}/gap"), cv);
                let f1 = b.fc(&format!("{base}/fc1"), g, (c / 4).max(1));
                let a1 = b.activation(&format!("{base}/sw"), f1, Activation::Swish);
                let f2 = b.fc(&format!("{base}/fc2"), a1, c);
                let a2 = b.activation(&format!("{base}/sig"), f2, Activation::Sigmoid);
                x = b.scale(&format!("{base}/scale"), cv, a2);
            } else {
                let k = *rng.choose(&[1usize, 3]);
                x = b.conv_bn_act(&format!("conv{id}"), x, k, 1, c, Activation::Relu);
            }
        }
        if s + 1 < stages {
            c *= 2;
            id += 1;
            x = b.conv_bn_act(&format!("down{id}"), x, 3, 2, c, Activation::Relu);
        }
    }
    let g = b.gap("gap", x);
    let _ = b.fc("fc", g, 10);
    b.finish()
}

#[test]
fn random_graphs_validate_and_analyze() {
    forall("random CNNs are well-formed", 60, |rng| {
        let g = random_cnn(rng);
        validate(&g).unwrap();
        let gg = analyze(&g);
        // grouping conserves nodes and MACs
        let n: usize = gg.groups.iter().map(|gr| gr.nodes.len()).sum();
        assert_eq!(n, g.nodes.len());
        let macs: u64 = gg.groups.iter().map(|gr| gr.macs(&gg.graph)).sum();
        assert_eq!(macs, g.total_macs());
    });
}

#[test]
fn allocator_never_aliases_live_buffers() {
    forall("no two live tensors share a physical buffer", 40, |rng| {
        let g = random_cnn(rng);
        let gg = analyze(&g);
        let cfg = AccelConfig::kcu1500_int8();
        let policy: Vec<ReuseMode> = (0..gg.groups.len())
            .map(|_| if rng.coin() { ReuseMode::Frame } else { ReuseMode::Row })
            .collect();
        let alloc = allocate(&gg, &policy, &cfg);
        // replay liveness: at each step, on-chip tensors in same buffer
        let consumers = gg.consumers();
        let mut owner: [Option<usize>; 3] = [None; 3];
        let mut last_use: Vec<usize> = (0..gg.groups.len())
            .map(|gi| consumers[gi].iter().map(|c| c.0).max().unwrap_or(gi))
            .collect();
        for gi in 0..gg.groups.len() {
            // free dead
            for b in owner.iter_mut() {
                if let Some(o) = *b {
                    if last_use[o] < gi {
                        *b = None;
                    }
                }
            }
            if let Loc::Buf(bu) = alloc.assigns[gi].out_loc {
                let b = bu as usize;
                if let Some(prev) = owner[b] {
                    // allowed only if prev is dead by now or was evicted
                    assert!(
                        last_use[prev] <= gi,
                        "buffer {b} reused while group {prev} still live at {gi}"
                    );
                }
                owner[b] = Some(gi);
            }
            // evicted tensors moved to DRAM — remove from owners
            let _ = &mut last_use;
        }
    });
}

#[test]
fn dram_total_is_bounded_by_baseline_plus_spills() {
    forall("dram(policy) <= baseline + spills", 40, |rng| {
        let g = random_cnn(rng);
        let gg = analyze(&g);
        let cfg = AccelConfig::kcu1500_int8();
        let policy: Vec<ReuseMode> = (0..gg.groups.len())
            .map(|_| if rng.coin() { ReuseMode::Frame } else { ReuseMode::Row })
            .collect();
        let alloc = allocate(&gg, &policy, &cfg);
        let d = dram_access(&gg, &policy, &alloc, &cfg);
        assert!(d.total <= d.baseline_once + d.spill_bytes);
        assert!(d.weight_bytes == gg.graph.total_weight_bytes(cfg.qw as u64));
    });
}

#[test]
fn more_frame_blocks_never_increase_fm_traffic() {
    // monotonicity along a single-segment sweep: moving the cut later
    // (more row blocks) cannot reduce feature-map DRAM traffic
    forall("fm traffic monotone in cut", 25, |rng| {
        let g = random_cnn(rng);
        let gg = analyze(&g);
        let cfg = AccelConfig::kcu1500_int8();
        let opt = Optimizer::new(&gg, &cfg);
        if opt.segs.len() != 1 {
            return; // only meaningful single-segment
        }
        let mut prev = None;
        for cut in 0..=opt.segs[0].len {
            let e = opt.evaluate(&[cut]);
            if let Some(p) = prev {
                assert!(
                    e.dram.fm_bytes + 1 >= p,
                    "cut {cut}: fm dropped from {p} to {}",
                    e.dram.fm_bytes
                );
            }
            prev = Some(e.dram.fm_bytes);
        }
    });
}

#[test]
fn latency_is_finite_positive_for_random_policies() {
    forall("sim latency sane", 40, |rng| {
        let g = random_cnn(rng);
        let gg = analyze(&g);
        let cfg = AccelConfig::kcu1500_int8();
        let policy: Vec<ReuseMode> = (0..gg.groups.len())
            .map(|_| if rng.coin() { ReuseMode::Frame } else { ReuseMode::Row })
            .collect();
        let alloc = allocate(&gg, &policy, &cfg);
        let t = simulate(&gg, &policy, &alloc, &cfg);
        assert!(t.latency_ms.is_finite() && t.latency_ms > 0.0);
        assert!(t.mac_efficiency > 0.0 && t.mac_efficiency <= 1.0);
    });
}

#[test]
fn optimizer_beats_or_matches_both_corners() {
    forall("optimum <= min(all-row, all-frame) when feasible", 20, |rng| {
        let g = random_cnn(rng);
        let gg = analyze(&g);
        let cfg = AccelConfig::kcu1500_int8();
        let opt = Optimizer::new(&gg, &cfg);
        let best = opt.optimize();
        if !best.feasible {
            return;
        }
        for corner in [
            opt.segs.iter().map(|s| match s.dir {
                shortcutfusion::optimizer::Direction::Dec => s.len,
                shortcutfusion::optimizer::Direction::Inc => 0,
            }).collect::<Vec<_>>(),
            opt.segs.iter().map(|s| match s.dir {
                shortcutfusion::optimizer::Direction::Dec => 0,
                shortcutfusion::optimizer::Direction::Inc => s.len,
            }).collect::<Vec<_>>(),
        ] {
            let e = opt.evaluate(&corner);
            if e.feasible {
                assert!(
                    best.latency_ms <= e.latency_ms * 1.0001,
                    "optimum {} > corner {}",
                    best.latency_ms,
                    e.latency_ms
                );
            }
        }
    });
}

#[test]
fn blocks_and_segments_tile_for_random_graphs() {
    forall("blocks/segments tile", 40, |rng| {
        let g = random_cnn(rng);
        let gg = analyze(&g);
        let blocks = basic_blocks(&gg);
        let mut next = 1;
        for b in &blocks {
            assert_eq!(b.start, next);
            next = b.end + 1;
        }
        assert_eq!(next, gg.groups.len());
        let segs = segments(&gg, &blocks);
        let total: usize = segs.iter().map(|s| s.len).sum();
        assert_eq!(total, blocks.len());
    });
}

#[test]
fn scheduler_conserves_requests_and_preserves_client_order() {
    // Random op sequences against the bare batch scheduler in virtual
    // time, mirroring what the threaded engine does: claim/join form
    // batches, workers execute their open batch strictly FIFO, and
    // queued tickets can expire when the clock advances. Two invariants
    // hold at *every* step:
    //   conservation  submitted == completed + failed + expired
    //                              + queued + in_flight
    //   client order  a client's executed tickets finish in submission
    //                 order (ticket ids are globally monotonic), even
    //                 across workers — cross-worker dispatch of a busy
    //                 client is blocked, same-worker joins queue behind.
    forall("scheduler conservation + per-client FIFO", 40, |rng| {
        let workers = rng.range(1, 3);
        let policy =
            if rng.coin() { BatchPolicy::Continuous } else { BatchPolicy::Window };
        let mut sched = Scheduler::new(
            SchedulerConfig {
                policy,
                max_batch: rng.range(1, 4),
                queue_capacity: rng.range(4, 12),
                deadline_ms: None,
            },
            workers,
        );
        // mirror of each worker's open batch in dispatch (FIFO) order
        let mut open: Vec<VecDeque<Ticket>> = vec![VecDeque::new(); workers];
        // client -> id of their last executed-or-abandoned ticket
        let mut last_done: HashMap<u64, u64> = HashMap::new();
        let mut finish = |t: &Ticket, last: &mut HashMap<u64, u64>| {
            if let Some(prev) = last.insert(t.client, t.id) {
                assert!(
                    prev < t.id,
                    "client {} finished ticket {} after {}",
                    t.client,
                    t.id,
                    prev
                );
            }
        };
        let mut now = 0.0f64;
        for _ in 0..60 {
            match rng.range(0, 5) {
                0 | 1 => {
                    // bias toward submission so queues actually build up
                    let client = rng.range(1, 4) as u64;
                    let deadline =
                        if rng.coin() { Some(now + rng.range(1, 20) as f64) } else { None };
                    let _ = sched.submit(client, now, deadline, 0);
                }
                2 => {
                    let w = rng.range(0, workers - 1);
                    open[w].extend(sched.claim(w, now));
                    open[w].extend(sched.join(w, now));
                }
                3 => {
                    // execute the front of a worker's open batch (FIFO,
                    // exactly like the engine's worker loop)
                    let w = rng.range(0, workers - 1);
                    if let Some(t) = open[w].pop_front() {
                        if rng.coin() {
                            let _ = sched.complete(w, t.id, now);
                        } else {
                            sched.fail(w, t.id);
                        }
                        finish(&t, &mut last_done);
                    }
                }
                4 => {
                    // overdue at dispatch: abandon the front unexecuted
                    let w = rng.range(0, workers - 1);
                    if let Some(t) = open[w].pop_front() {
                        sched.abandon(w, t.id);
                        finish(&t, &mut last_done);
                    }
                }
                _ => {
                    now += rng.range(0, 5) as f64;
                    // expired tickets leave the queue; their waiters get
                    // a typed error in the engine — nothing to mirror
                    let _ = sched.expire(now);
                }
            }
            let c = sched.counters();
            assert_eq!(
                c.submitted,
                c.completed
                    + c.failed
                    + c.expired
                    + sched.queued() as u64
                    + sched.in_flight() as u64,
                "conservation broken after an op (policy {policy:?})"
            );
        }
        // drain: every accepted request must reach a terminal state
        let mut guard = 0;
        while sched.queued() + sched.in_flight() > 0 {
            guard += 1;
            assert!(guard < 10_000, "drain did not converge");
            for w in 0..workers {
                open[w].extend(sched.claim(w, now));
                open[w].extend(sched.join(w, now));
                if let Some(t) = open[w].pop_front() {
                    let _ = sched.complete(w, t.id, now);
                    finish(&t, &mut last_done);
                }
            }
        }
        let c = sched.counters();
        assert_eq!(c.submitted, c.completed + c.failed + c.expired);
        assert_eq!(c.deadline_misses(), c.expired + c.late);
    });
}

#[test]
fn se_groups_always_fit_three_buffers() {
    forall("SE blocks never spill", 30, |rng| {
        let g = random_cnn(rng);
        let gg = analyze(&g);
        let cfg = AccelConfig::kcu1500_int8();
        let policy = vec![ReuseMode::Frame; gg.groups.len()];
        let alloc = allocate(&gg, &policy, &cfg);
        // linear chains with residual/SE blocks must fit {0,1,2}
        let has_concat = gg.groups.iter().any(|gr| gr.kind == GroupKind::Concat);
        if !has_concat {
            assert_eq!(alloc.spill_events, 0, "spilled a plain residual/SE net");
        }
    });
}
