//! Execution-backend cross-checks and serving-engine concurrency tests.
//!
//! * `ReferenceBackend` over a packed program is bit-identical to calling
//!   funcsim's `Executor` directly on the compile-time artifacts;
//! * `VirtualAccelBackend` traffic equals the analytical eq-8/9 DRAM
//!   model (the same identity `sim/traffic.rs` asserts for the compile
//!   path) and its latency equals the compile-time timing simulation;
//! * the `InferenceEngine` demonstrably overlaps ≥ 4 concurrent requests
//!   across ≥ 2 backend workers.

use std::sync::{Arc, Barrier};

use shortcutfusion::compiler::Compiler;
use shortcutfusion::config::AccelConfig;
use shortcutfusion::engine::{
    EngineConfig, ExecutionBackend, InferenceEngine, ReferenceBackend, RunResult,
    VirtualAccelBackend,
};
use shortcutfusion::funcsim::{Executor, Params, Tensor};
use shortcutfusion::optimizer::dram_access;
use shortcutfusion::program::Program;
use shortcutfusion::testutil::Rng;
use shortcutfusion::zoo;

#[test]
fn reference_backend_is_bit_identical_to_direct_executor() {
    let graph = zoo::tinynet();
    let compiler = Compiler::new(AccelConfig::kcu1500_int8());
    let analyzed = compiler.analyze(&graph).unwrap();
    let params = Params::random(&analyzed.grouped, 11);
    let compiler = compiler.with_params(params.clone());
    let lowered = compiler
        .lower(&compiler.allocate(&compiler.optimize(&analyzed).unwrap()).unwrap())
        .unwrap();
    let program = compiler.pack(&lowered).unwrap();
    // round-trip through bytes so the check covers the *loaded* artifact
    let program = Program::from_bytes(&program.to_bytes()).unwrap();

    let shape = program.input_shape();
    let mut rng = Rng::from_seed(3);
    for _ in 0..3 {
        let input = Tensor::from_vec(shape, rng.i8_vec(shape.numel()));
        let packed = ReferenceBackend.run(&program, &input).unwrap();
        let direct = Executor::new(&analyzed.grouped, &params).run(&input).unwrap();
        assert_eq!(
            packed.output.as_ref().unwrap(),
            direct.last().unwrap(),
            "packed-program execution diverged from the direct executor"
        );
    }
}

#[test]
fn virtual_backend_matches_analytical_traffic_and_compile_time_timing() {
    let cfg = AccelConfig::kcu1500_int8();
    let compiler = Compiler::new(cfg.clone());
    for name in ["resnet18", "efficientnet-b0", "unet"] {
        let g = zoo::by_name(name, 64).unwrap();
        let analyzed = compiler.analyze(&g).unwrap();
        let lowered = compiler
            .lower(&compiler.allocate(&compiler.optimize(&analyzed).unwrap()).unwrap())
            .unwrap();
        let simulated = compiler.simulate(&lowered).unwrap();
        let program = compiler.pack(&lowered).unwrap();
        let program = Program::from_bytes(&program.to_bytes()).unwrap();

        let r = VirtualAccelBackend.run(&program, &Tensor::zeros(program.input_shape())).unwrap();

        // traffic: replayed DRAM bytes + spills == analytical fm + weights
        let analytical =
            dram_access(&lowered.grouped, &lowered.evaluation.policy, &lowered.alloc, &cfg);
        assert_eq!(
            r.dram_bytes.unwrap() + analytical.spill_bytes,
            analytical.fm_bytes + analytical.weight_bytes,
            "{name}: packed-program traffic disagrees with the analytical model"
        );

        // latency: the packed instructions drive the same timing walk
        assert_eq!(
            r.model_latency_ms.unwrap(),
            simulated.timing.latency_ms,
            "{name}: packed-program latency disagrees with the compile-time simulation"
        );
    }
}

/// Test backend that blocks every `run` on a 2-party barrier: a request
/// can only finish while a *second* worker is simultaneously inside
/// `run`, so completing at all proves cross-worker overlap.
struct GateBackend {
    gate: Barrier,
}

impl ExecutionBackend for GateBackend {
    fn name(&self) -> &'static str {
        "gate"
    }

    fn run(&self, _program: &Program, _input: &Tensor) -> shortcutfusion::Result<RunResult> {
        self.gate.wait();
        Ok(RunResult {
            backend: "gate",
            output: None,
            model_latency_ms: Some(1.0),
            dram_bytes: None,
            cold_load_ms: None,
            traffic_classes: None,
        })
    }
}

fn tinynet_program() -> Arc<Program> {
    Arc::new(shortcutfusion::testutil::pack_program(&zoo::tinynet(), None))
}

#[test]
fn engine_overlaps_four_requests_across_two_workers() {
    let program = tinynet_program();
    let shape = program.input_shape();
    let mut engine = InferenceEngine::new_paused(
        program,
        Arc::new(GateBackend { gate: Barrier::new(2) }),
        EngineConfig { workers: 2, queue_capacity: 8, max_batch: 2, ..EngineConfig::default() },
    );
    // queue all four requests before any worker exists, so each of the
    // two workers deterministically claims a batch of two
    let pending: Vec<_> = (0..4).map(|_| engine.submit(Tensor::zeros(shape)).unwrap()).collect();
    engine.start();
    let mut workers_seen = std::collections::HashSet::new();
    for p in pending {
        let done = p.wait().unwrap();
        workers_seen.insert(done.worker);
    }
    let stats = engine.shutdown();
    assert_eq!(stats.completed, 4);
    assert!(
        stats.peak_in_flight >= 4,
        "expected >= 4 requests in flight, saw {}",
        stats.peak_in_flight
    );
    assert!(
        workers_seen.len() >= 2,
        "expected >= 2 workers to serve the batch, saw {:?}",
        workers_seen
    );
    assert!(stats.per_worker.iter().filter(|&&n| n > 0).count() >= 2);
}

#[test]
fn engine_serves_a_real_backend_under_concurrency() {
    let program = tinynet_program();
    let shape = program.input_shape();
    let engine = InferenceEngine::new(
        program.clone(),
        Arc::new(VirtualAccelBackend),
        EngineConfig { workers: 4, queue_capacity: 16, max_batch: 4, ..EngineConfig::default() },
    );
    let pending: Vec<_> =
        (0..32).map(|_| engine.submit(Tensor::zeros(shape)).unwrap()).collect();
    let mut latencies = Vec::new();
    for p in pending {
        let done = p.wait().unwrap();
        latencies.push(done.result.model_latency_ms.unwrap());
    }
    let stats = engine.shutdown();
    assert_eq!(stats.completed, 32);
    assert_eq!(stats.failed, 0);
    // all requests run the same program on the same virtual hardware:
    // the timing model must be input-independent and deterministic
    assert!(latencies.iter().all(|&l| l == latencies[0]));
    assert_eq!(stats.p50_ms, latencies[0]);
    assert_eq!(stats.p95_ms, latencies[0]);
    assert!(stats.throughput_rps > 0.0);
}

#[test]
fn reference_backend_failures_are_reported_per_request() {
    // a program without packed params: reference execution fails typed,
    // the engine counts it, and the pending handle receives the error
    let program = tinynet_program();
    let shape = program.input_shape();
    let engine = InferenceEngine::new(
        program,
        Arc::new(ReferenceBackend),
        EngineConfig { workers: 1, queue_capacity: 4, max_batch: 2, ..EngineConfig::default() },
    );
    let p = engine.submit(Tensor::zeros(shape)).unwrap();
    assert!(p.wait().is_err());
    let stats = engine.shutdown();
    assert_eq!(stats.failed, 1);
    assert_eq!(stats.completed, 0);
}
