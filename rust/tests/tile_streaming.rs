//! Depth-first tile-streaming integration tests.
//!
//! Pins the external guarantees of the tile subsystem
//! ([`shortcutfusion::tile`]):
//!
//! * tiled functional execution is bit-identical to the whole-frame
//!   reference on every zoo model;
//! * in a constrained-SRAM corner where whole-frame reuse falls back to
//!   row streaming, the `tile` strategy cuts modeled feature-map DRAM
//!   bytes below every *feasible* existing strategy at equal SRAM (the
//!   acceptance corner), and its points land on the explorer's Pareto
//!   front;
//! * the halo overhead shrinks monotonically as the tile height grows
//!   and vanishes at full-frame tiles, so tiled costs converge to the
//!   whole-frame model;
//! * packed tile programs round-trip byte-identically, the plan
//!   recovered from the wire matches the compiler's, and the
//!   instruction-level replay reproduces the tile-aware analytical
//!   DRAM model exactly (the keystone cross-check).

use std::sync::Arc;

use shortcutfusion::alloc::allocate;
use shortcutfusion::analyzer::analyze;
use shortcutfusion::compiler::{
    strategy, Compiler, ReuseStrategy, Session, TileStreamingStrategy,
};
use shortcutfusion::config::AccelConfig;
use shortcutfusion::engine::{ExecutionBackend, VirtualAccelBackend};
use shortcutfusion::explorer::SearchSpace;
use shortcutfusion::funcsim::{Executor, Params, Tensor};
use shortcutfusion::optimizer::dram_access;
use shortcutfusion::program::Program;
use shortcutfusion::sim;
use shortcutfusion::testutil::Rng;
use shortcutfusion::tile::{self, exec::run_tiled, TilePlan};
use shortcutfusion::zoo;

/// Small build resolution per model, mirroring the import round-trip
/// suite: large enough for every stride/upsample chain, small enough
/// that debug-mode funcsim stays fast.
fn test_input(name: &str) -> usize {
    match name {
        "retinanet" | "efficientdet-d0" => 64,
        _ => 32,
    }
}

/// A config whose eq-(10) feasibility is decided by the byte budget
/// alone (BRAM made a non-constraint, like the explorer ablation).
fn budgeted(sram_budget: usize) -> AccelConfig {
    let mut cfg = AccelConfig::kcu1500_int8();
    cfg.sram_budget = sram_budget;
    cfg.bram18k_total = 1_000_000;
    cfg
}

fn registry(name: &str) -> Arc<dyn ReuseStrategy> {
    Arc::from(strategy::by_name(name).unwrap())
}

#[test]
fn every_zoo_model_is_bit_identical_under_tiling() {
    let cfg = AccelConfig::kcu1500_int8();
    let mut tiled_models = 0;
    for &name in zoo::MODEL_NAMES {
        let gg = analyze(&zoo::by_name(name, test_input(name)).unwrap());
        let plan = tile::plan(&gg, &cfg, 4);
        if !plan.is_empty() {
            tiled_models += 1;
        }
        let params = Params::random(&gg, 11);
        let mut rng = Rng::from_seed(12);
        let shape = gg.graph.input().out_shape;
        let input = Tensor::from_vec(shape, rng.i8_vec(shape.numel()));
        let reference = Executor::new(&gg, &params).run(&input).unwrap();
        let tiled = run_tiled(&gg, &params, &input, &plan).unwrap();
        // Compare every tensor the completeness contract covers:
        // non-region nodes and region-last group outputs (which include
        // the network outputs).
        for (ni, node) in gg.graph.nodes.iter().enumerate() {
            let gid = gg.node_group[ni];
            let covered = match plan.region_of(gid.0) {
                None => true,
                Some(r) => {
                    gid.0 == r.last && *gg.groups[gid.0].nodes.last().unwrap() == node.id
                }
            };
            if covered {
                assert_eq!(
                    reference[ni].data, tiled[ni].data,
                    "{name}: node {ni} ({}) diverges under 4-row tiles",
                    node.name
                );
            }
        }
    }
    // the sweep must exercise real tiling, not empty-plan fallbacks
    assert!(tiled_models >= 2, "only {tiled_models} models formed tile regions");
}

#[test]
fn pinned_models_form_regions_at_64px() {
    let cfg = AccelConfig::kcu1500_int8();
    for (name, t) in [("resnet18", 4), ("yolov2", 8), ("vgg16-conv", 8)] {
        let gg = analyze(&zoo::by_name(name, 64).unwrap());
        assert!(!tile::plan(&gg, &cfg, t).is_empty(), "{name}: no region at t={t}");
    }
}

/// The acceptance corner: at 3 MB the deep 3×3×512×512 weight preload
/// (2.36 MB, eq. 1) leaves whole-frame reuse no headroom — fixed-frame
/// is infeasible and the cut-point optimizer falls back to row-heavy
/// policies that stream feature maps through DRAM. Depth-first tiling
/// keeps those interiors on chip and must beat every *feasible*
/// existing strategy on modeled feature-map DRAM bytes at equal SRAM.
#[test]
fn tile_cuts_fm_traffic_where_whole_frame_reuse_falls_back_to_rows() {
    let session = Session::new();
    for model in ["vgg16-conv", "resnet34"] {
        let cfg = budgeted(3_000_000);
        let mut best_feasible_fm = u64::MAX;
        let mut any_feasible = false;
        for &name in strategy::STRATEGY_NAMES.iter().filter(|&&n| n != "tile") {
            let r = session.compile_with(model, 224, &cfg, &registry(name)).unwrap();
            if name == "fixed-frame" {
                assert!(!r.evaluation.feasible, "{model}: all-frame must not fit 3 MB");
            }
            if r.evaluation.feasible {
                any_feasible = true;
                best_feasible_fm = best_feasible_fm.min(r.evaluation.dram.fm_bytes);
            }
        }
        assert!(any_feasible, "{model}: the row fallback must fit 3 MB");

        let rt = session.compile_with(model, 224, &cfg, &registry("tile")).unwrap();
        let plan = rt.evaluation.tiles.as_ref().expect("a tile plan must form");
        assert!(!plan.is_empty());
        assert!(rt.evaluation.feasible, "{model}: tile must fit 3 MB");
        assert!(
            rt.evaluation.dram.fm_bytes < best_feasible_fm,
            "{model}: tile fm bytes {} !< best whole-frame fm bytes {}",
            rt.evaluation.dram.fm_bytes,
            best_feasible_fm
        );
    }
}

/// Same corner through the explorer: the tile point is feasible,
/// beats the row fallback on feature-map traffic, and earns a spot on
/// the Pareto front (nothing dominates its DRAM total).
#[test]
fn tile_points_reach_the_pareto_front_in_the_constrained_corner() {
    let session = Session::new();
    let cfg = budgeted(3_000_000);

    // pinned 16-row tiles cover the 7×7/14×14 tail in single tiles, so
    // the deep weight preloads leave eq. (1) entirely (the SRAM swap is
    // unit-pinned in optimizer::bufcalc)
    let row = session.compile_with("resnet18", 224, &cfg, &registry("fixed-row")).unwrap();
    let t16 = session.compile_with("resnet18", 224, &cfg, &registry("tile-16")).unwrap();
    assert!(row.evaluation.feasible);
    assert!(t16.evaluation.feasible);
    assert!(t16.evaluation.tiles.is_some());
    assert!(
        t16.evaluation.dram.fm_bytes < row.evaluation.dram.fm_bytes,
        "tile-16 fm {} !< fixed-row fm {}",
        t16.evaluation.dram.fm_bytes,
        row.evaluation.dram.fm_bytes
    );
    assert!(
        t16.evaluation.sram.total < row.evaluation.sram.total,
        "tile-16 sram {} !< fixed-row sram {}",
        t16.evaluation.sram.total,
        row.evaluation.sram.total
    );

    let exploration = SearchSpace::new(budgeted(3_000_000))
        .model("resnet18")
        .input_sizes(&[224])
        .ablation_strategies()
        .explore(&session, 2)
        .unwrap();
    assert!(exploration.failures.is_empty());
    let front = exploration.pareto_front("resnet18");
    assert!(
        front.points.iter().any(|p| p.strategy_name() == "tile"),
        "no tile point on the front: {:?}",
        front.points.iter().map(|p| p.strategy_name()).collect::<Vec<_>>()
    );
}

/// Halo-size property: for a fixed region set, the halo re-read bytes
/// are non-increasing in the tile height, and at full-frame tiles
/// (one tile per region) both overhead terms are exactly zero — the
/// tiled cost model degenerates to the whole-frame model.
#[test]
fn halo_overhead_vanishes_as_tiles_grow_to_the_frame() {
    let gg = analyze(&zoo::by_name("vgg16-conv", 224).unwrap());
    let cfg = budgeted(1_000_000);
    let plan = tile::plan(&gg, &cfg, 4);
    assert!(!plan.is_empty());
    let at = |rows: usize| TilePlan {
        regions: plan
            .regions
            .iter()
            .map(|r| {
                let mut r = r.clone();
                r.tile_rows = rows;
                r
            })
            .collect(),
    };
    let mut prev = u64::MAX;
    for t in [4usize, 8, 16, 32, 64, 224] {
        let o = tile::overheads(&gg, &cfg, &at(t));
        assert!(
            o.halo_fm_extra <= prev,
            "halo grew from {prev} to {} at t={t}",
            o.halo_fm_extra
        );
        prev = o.halo_fm_extra;
    }
    // 224 rows >= every out_h: single-tile regions, no halo, and no
    // weight re-streaming ((n_tiles - 1) · W = 0)
    let full = tile::overheads(&gg, &cfg, &at(224));
    assert_eq!(full.halo_fm_extra, 0);
    assert_eq!(full.weight_extra, 0);
}

#[test]
fn tiled_programs_round_trip_byte_identically_and_replay_their_plan() {
    let cfg = AccelConfig::kcu1500_int8();
    let compiler =
        Compiler::with_strategy(cfg, Arc::new(TileStreamingStrategy { tile_rows: Some(4) }));
    let g = zoo::by_name("resnet18", 64).unwrap();
    let analyzed = compiler.analyze(&g).unwrap();
    let lowered = compiler
        .lower(&compiler.allocate(&compiler.optimize(&analyzed).unwrap()).unwrap())
        .unwrap();
    let want = lowered.evaluation.tiles.clone().expect("tile-4 must plan resnet18@64");
    assert!(!want.is_empty());
    let program = compiler.pack(&lowered).unwrap();

    // the schedule travels in the instruction words, no side channel
    assert_eq!(TilePlan::from_stream(program.stream()), want);

    let bytes = program.to_bytes();
    let loaded = Program::from_bytes(&bytes).unwrap();
    assert_eq!(loaded.to_bytes(), bytes, "re-save is not byte-identical");
    assert_eq!(loaded.stream().words, program.stream().words);
    assert_eq!(TilePlan::from_stream(loaded.stream()), want);

    // the virtual accelerator recovers the plan and costs the program
    let input = Tensor::zeros(loaded.input_shape());
    let r = VirtualAccelBackend.run(&loaded, &input).unwrap();
    assert!(r.model_latency_ms.unwrap() > 0.0);
    assert!(r.dram_bytes.unwrap() > 0);
}

#[test]
fn whole_frame_programs_stay_untiled_on_the_wire() {
    let program =
        shortcutfusion::testutil::pack_program(&zoo::by_name("resnet18", 64).unwrap(), None);
    assert!(TilePlan::from_stream(program.stream()).is_empty());
    for ins in &program.stream().instrs {
        assert_eq!(ins.tile_rows, 0);
        assert!(!ins.tile_first && !ins.tile_weight_stream);
    }
}

/// The keystone cross-check, tiled: replaying the packed stream (which
/// re-derives the plan from the tile fields) must reproduce the
/// evaluation's eq-8/9 + overhead accounting byte-for-byte.
#[test]
fn tiled_replay_matches_the_analytical_model() {
    let cfg = AccelConfig::kcu1500_int8();
    let compiler = Compiler::with_strategy(
        cfg.clone(),
        Arc::new(TileStreamingStrategy { tile_rows: Some(4) }),
    );
    let r = compiler.compile(&zoo::by_name("resnet18", 64).unwrap()).unwrap();
    let plan = r.evaluation.tiles.as_ref().expect("tile-4 must plan resnet18@64");

    // rebuild the allocation exactly as the compiler did: base all-row
    // placement, then the tile overlay pinning region interiors on chip
    let mut alloc = allocate(&r.grouped, &r.evaluation.policy, &cfg);
    tile::apply_overlay(&mut alloc.assigns, &r.grouped, plan);
    let staged: Vec<bool> = alloc.assigns.iter().map(|a| a.staged_input).collect();
    let also: Vec<bool> = alloc.assigns.iter().map(|a| a.also_dram).collect();

    let replayed = sim::replay(&r.grouped, &r.stream, &staged, &also, &cfg);
    let mut analytical = dram_access(&r.grouped, &r.evaluation.policy, &alloc, &cfg);
    let o = tile::overheads(&r.grouped, &cfg, plan);
    analytical.fm_bytes += o.halo_fm_extra;
    analytical.weight_bytes += o.weight_extra;

    assert_eq!(
        replayed.fm_total() + analytical.spill_bytes,
        analytical.fm_bytes,
        "replayed {} + spills {} != analytical {}",
        replayed.fm_total(),
        analytical.spill_bytes,
        analytical.fm_bytes
    );
    assert_eq!(replayed.weight_read, analytical.weight_bytes, "weights");
    // and the folded terms are exactly what the evaluation reported
    assert_eq!(analytical.fm_bytes, r.evaluation.dram.fm_bytes);
    assert_eq!(analytical.weight_bytes, r.evaluation.dram.weight_bytes);
}
