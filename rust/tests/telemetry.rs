//! End-to-end telemetry integration tests.
//!
//! Pins the external guarantees of [`shortcutfusion::telemetry`]:
//!
//! * the Chrome trace-event export of a served workload is
//!   **byte-deterministic** under a [`VirtualClock`] — every timestamp
//!   is drawn from the engine's injected clock, the recorder sorts
//!   before serialising, and run-span durations come from the timing
//!   model, so two identical runs export identical bytes;
//! * per-tensor-class DRAM attribution **conserves the eq-(8)/(9)
//!   totals** for every zoo model under every registered reuse
//!   strategy — no byte unclassified, no byte double-counted;
//! * the paper's headline number is regression-gated: the shortcut
//!   class is a large share of feature-map traffic under row-streaming
//!   baselines on residual networks, and the cut-point optimizer and
//!   the depth-first tile streamer both shrink it.

use std::sync::Arc;

use shortcutfusion::compiler::{strategy, ReuseStrategy, Session};
use shortcutfusion::config::AccelConfig;
use shortcutfusion::engine::{
    EngineConfig, InferenceEngine, VirtualAccelBackend, VirtualClock,
};
use shortcutfusion::funcsim::Tensor;
use shortcutfusion::telemetry::TraceRecorder;
use shortcutfusion::testutil::pack_program;
use shortcutfusion::zoo;

fn registry(name: &str) -> Arc<dyn ReuseStrategy> {
    Arc::from(strategy::by_name(name).unwrap())
}

/// Serve three requests through a paused engine on a virtual clock and
/// return the exported Chrome trace.
fn serve_and_export() -> String {
    let program = Arc::new(pack_program(&zoo::tinynet(), None));
    let shape = program.input_shape();
    let clock = Arc::new(VirtualClock::new());
    let rec = Arc::new(TraceRecorder::new());
    let mut engine = InferenceEngine::new_paused_with_clock(
        program,
        Arc::new(VirtualAccelBackend),
        EngineConfig { workers: 1, queue_capacity: 8, max_batch: 4, ..EngineConfig::default() },
        clock.clone(),
    )
    .with_trace(rec.clone());
    // all submits land at controlled virtual times before any worker
    // exists, so claim order and every timestamp are reproducible
    let mut pending = Vec::new();
    for _ in 0..3 {
        clock.advance_ms(5.0);
        pending.push(engine.submit(Tensor::zeros(shape)).unwrap());
    }
    engine.start();
    for p in pending {
        p.wait().unwrap();
    }
    engine.shutdown();
    rec.export_chrome()
}

#[test]
fn trace_export_is_byte_deterministic_under_virtual_clock() {
    let a = serve_and_export();
    let b = serve_and_export();
    assert_eq!(a, b, "two identical virtual-clock runs must export identical bytes");
    // structural sanity of the export itself
    assert!(a.starts_with('{') && a.ends_with('\n'));
    assert!(a.contains("\"displayTimeUnit\""));
    assert!(a.contains("\"traceEvents\""));
    for name in ["submit", "claim", "run", "complete"] {
        assert_eq!(
            a.matches(&format!("\"name\": \"{name}\"")).count(),
            3,
            "expected one {name:?} event per request"
        );
    }
}

#[test]
fn attribution_conserves_totals_for_every_model_and_strategy() {
    let session = Session::new();
    let cfg = AccelConfig::kcu1500_int8();
    for &model in zoo::MODEL_NAMES {
        for &name in strategy::STRATEGY_NAMES {
            let r = session.compile_with(model, 64, &cfg, &registry(name)).unwrap();
            let d = &r.evaluation.dram;
            assert_eq!(
                d.classes.total(),
                d.total,
                "{model} [{name}]: class attribution must conserve the eq-9 total"
            );
            assert_eq!(
                d.classes.fm_total(),
                d.fm_bytes,
                "{model} [{name}]: feature-map classes must conserve fm_bytes"
            );
            assert_eq!(
                d.classes.weights, d.weight_bytes,
                "{model} [{name}]: weight class must equal the eq-8 weight term"
            );
        }
    }
}

#[test]
fn shortcut_share_is_large_under_row_baseline_and_drops_under_cutpoint_and_tile() {
    let session = Session::new();
    // BRAM made a non-constraint so feasibility is decided by the byte
    // budget alone — the same corner the tile acceptance test pins
    let mut cfg = AccelConfig::kcu1500_int8();
    cfg.sram_budget = 3_000_000;
    cfg.bram18k_total = 1_000_000;
    for model in ["resnet18", "resnet34"] {
        let share = |name: &str| {
            let r = session.compile_with(model, 224, &cfg, &registry(name)).unwrap();
            r.evaluation.dram.classes.shortcut_share()
        };
        let row = share("fixed-row");
        assert!(
            row > 0.10,
            "{model}: row-streaming shortcut share {row:.3} should be the paper's \
             large baseline fraction"
        );
        let cut = share("cutpoint");
        assert!(
            cut < row,
            "{model}: cut-point reuse must shrink the shortcut share ({cut:.3} !< {row:.3})"
        );
        let tile = share("tile");
        assert!(
            tile < row,
            "{model}: tile streaming must shrink the shortcut share ({tile:.3} !< {row:.3})"
        );
    }
}
