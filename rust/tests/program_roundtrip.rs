//! Artifact-level properties: randomized encode/decode round-trips and
//! the packed `Program` container's save → load → byte-identical re-save
//! guarantee over the whole model zoo.

use shortcutfusion::compiler::{CompileError, Compiler};
use shortcutfusion::config::AccelConfig;
use shortcutfusion::graph::Shape;
use shortcutfusion::isa::{decode, encode, WORDS_PER_INSTR};
use shortcutfusion::program::format::{fnv1a32, unwrap as unwrap_container};
use shortcutfusion::program::{Program, ShardBoundary, TensorDesc};
use shortcutfusion::shard::Partitioner;
use shortcutfusion::testutil::{forall, random_instruction};
use shortcutfusion::zoo;

#[test]
fn encode_decode_roundtrip_over_randomized_instructions() {
    forall("encode∘decode = id over the instruction space", 2000, |rng| {
        let i = random_instruction(rng);
        let words = encode(&i);
        assert_eq!(words.len(), WORDS_PER_INSTR);
        assert_eq!(decode(&words).unwrap(), i);
    });
}

#[test]
fn decode_never_panics_on_random_words() {
    // decode must reject or accept — never panic — whatever 11 words it
    // is handed (a corrupted stream reaches it before any checksum in
    // unit-level use).
    forall("decode is total", 2000, |rng| {
        let mut words = [0u32; WORDS_PER_INSTR];
        for w in words.iter_mut() {
            *w = rng.next_u64() as u32;
        }
        let _ = decode(&words);
    });
}

#[test]
fn program_save_load_resave_is_byte_identical_for_every_zoo_model() {
    let compiler = Compiler::new(AccelConfig::kcu1500_int8());
    for &name in zoo::MODEL_NAMES {
        let g = zoo::by_name(name, zoo::default_input(name)).unwrap();
        let analyzed = compiler.analyze(&g).unwrap();
        let lowered = compiler
            .lower(&compiler.allocate(&compiler.optimize(&analyzed).unwrap()).unwrap())
            .unwrap();
        let program = compiler.pack(&lowered).unwrap();

        let bytes = program.to_bytes();
        let loaded = Program::from_bytes(&bytes).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(loaded.to_bytes(), bytes, "{name}: re-save is not byte-identical");

        // the loaded program is the same program, not merely equal bytes
        assert_eq!(loaded.model(), program.model(), "{name}");
        assert_eq!(loaded.strategy(), "cutpoint", "{name}");
        assert_eq!(loaded.cfg(), program.cfg(), "{name}");
        assert_eq!(loaded.stream().words, program.stream().words, "{name}");
        assert_eq!(loaded.policy(), program.policy(), "{name}");
        assert_eq!(
            loaded.grouped().groups.len(),
            program.grouped().groups.len(),
            "{name}"
        );
    }
}

#[test]
fn program_file_round_trip() {
    let compiler = Compiler::new(AccelConfig::kcu1500_int8());
    let analyzed = compiler.analyze(&zoo::tinynet()).unwrap();
    let lowered = compiler
        .lower(&compiler.allocate(&compiler.optimize(&analyzed).unwrap()).unwrap())
        .unwrap();
    let program = compiler.pack(&lowered).unwrap();

    let dir = std::env::temp_dir().join("sf_program_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("tinynet.sfp");
    program.save(&path).unwrap();
    let loaded = Program::load(&path).unwrap();
    assert_eq!(loaded.to_bytes(), program.to_bytes());
}

#[test]
fn random_payload_corruption_is_always_detected() {
    let compiler = Compiler::new(AccelConfig::kcu1500_int8());
    let analyzed = compiler.analyze(&zoo::tinynet()).unwrap();
    let lowered = compiler
        .lower(&compiler.allocate(&compiler.optimize(&analyzed).unwrap()).unwrap())
        .unwrap();
    let bytes = compiler.pack(&lowered).unwrap().to_bytes();

    forall("bit flips never load", 200, |rng| {
        let mut bad = bytes.clone();
        let pos = rng.below(bad.len());
        let bit = 1u8 << rng.below(8);
        bad[pos] ^= bit;
        match Program::from_bytes(&bad) {
            Err(_) => {}
            Ok(_) => panic!("flip of bit {bit:#x} at byte {pos} loaded successfully"),
        }
    });
}

#[test]
fn container_checksum_covers_the_whole_payload() {
    let compiler = Compiler::new(AccelConfig::kcu1500_int8());
    let analyzed = compiler.analyze(&zoo::tinynet()).unwrap();
    let lowered = compiler
        .lower(&compiler.allocate(&compiler.optimize(&analyzed).unwrap()).unwrap())
        .unwrap();
    let bytes = compiler.pack(&lowered).unwrap().to_bytes();
    let payload = unwrap_container(&bytes).unwrap();
    // header stores fnv1a32(payload); recompute independently
    let stored = u32::from_le_bytes(bytes[12..16].try_into().unwrap());
    assert_eq!(stored, fnv1a32(payload));
}

/// Shard programs: every boundary-stamped artifact must round-trip
/// save → load → re-save byte-identically with its descriptors intact.
#[test]
fn shard_boundary_descriptors_survive_the_round_trip_byte_identically() {
    let plan = Partitioner::homogeneous(AccelConfig::kcu1500_int8(), 2)
        .unwrap()
        .plan(&zoo::tinynet())
        .unwrap();
    let programs = plan.pack().unwrap();
    assert_eq!(programs.len(), 2);
    for (i, p) in programs.iter().enumerate() {
        let b = p.boundary().expect("sharded artifact carries its boundary");
        assert_eq!((b.index, b.count), (i, 2));
        assert_eq!(b.ingress.is_none(), i == 0);
        assert_eq!(b.egress.is_none(), i == 1);

        let bytes = p.to_bytes();
        let loaded = Program::from_bytes(&bytes).unwrap_or_else(|e| panic!("shard {i}: {e}"));
        assert_eq!(loaded.to_bytes(), bytes, "shard {i}: re-save is not byte-identical");
        assert_eq!(loaded.boundary(), p.boundary(), "shard {i}: descriptors changed");
        assert_eq!(loaded.input_shape(), p.input_shape(), "shard {i}");
    }
    // consecutive descriptors agree: shard 0's egress is the tensor
    // shard 1's graph ingests
    let egress = programs[0].boundary().unwrap().egress.clone().unwrap();
    let ingress = programs[1].boundary().unwrap().ingress.clone().unwrap();
    assert_eq!(egress, ingress);
    assert_eq!(ingress.shape, programs[1].input_shape());
}

/// Bit flips anywhere in a sharded artifact — header included — must be
/// rejected, exactly like the unsharded container property above.
#[test]
fn corrupt_sharded_artifacts_are_rejected() {
    let plan = Partitioner::homogeneous(AccelConfig::kcu1500_int8(), 2)
        .unwrap()
        .plan(&zoo::tinynet())
        .unwrap();
    let bytes = plan.pack().unwrap()[0].to_bytes();
    forall("sharded bit flips never load", 200, |rng| {
        let mut bad = bytes.clone();
        let pos = rng.below(bad.len());
        bad[pos] ^= 1u8 << rng.below(8);
        assert!(
            Program::from_bytes(&bad).is_err(),
            "flip at byte {pos} loaded successfully"
        );
    });
    // truncated header
    assert!(Program::from_bytes(&bytes[..12]).is_err());
}

/// Self-inconsistent boundary records are rejected at stamp time.
#[test]
fn inconsistent_shard_boundaries_are_rejected() {
    let program = shortcutfusion::testutil::pack_program(&zoo::tinynet(), None);
    let desc = |shape: Shape| TensorDesc { name: "stem/relu".into(), shape };
    let input = program.input_shape();
    // a pipeline needs >= 2 shards
    assert!(program
        .clone()
        .with_boundary(ShardBoundary { index: 0, count: 1, ingress: None, egress: None })
        .is_err());
    // index out of range
    assert!(program
        .clone()
        .with_boundary(ShardBoundary {
            index: 2,
            count: 2,
            ingress: Some(desc(input)),
            egress: None,
        })
        .is_err());
    // first shard must not declare an ingress
    assert!(program
        .clone()
        .with_boundary(ShardBoundary {
            index: 0,
            count: 2,
            ingress: Some(desc(input)),
            egress: Some(desc(input)),
        })
        .is_err());
    // ingress shape must match the graph's input feed
    assert!(program
        .clone()
        .with_boundary(ShardBoundary {
            index: 1,
            count: 2,
            ingress: Some(desc(Shape::new(1, 1, 1))),
            egress: None,
        })
        .is_err());
    // egress must name a node of the shard graph
    assert!(program
        .clone()
        .with_boundary(ShardBoundary {
            index: 0,
            count: 2,
            ingress: None,
            egress: Some(TensorDesc { name: "no-such-node".into(), shape: input }),
        })
        .is_err());
}

#[test]
fn cross_config_pack_is_rejected() {
    let a = Compiler::new(AccelConfig::kcu1500_int8());
    let b = Compiler::new(AccelConfig::table2_int16());
    let analyzed = a.analyze(&zoo::tinynet()).unwrap();
    let lowered = a
        .lower(&a.allocate(&a.optimize(&analyzed).unwrap()).unwrap())
        .unwrap();
    assert!(matches!(b.pack(&lowered), Err(CompileError::StageMismatch(_))));
}
