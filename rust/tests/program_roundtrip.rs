//! Artifact-level properties: randomized encode/decode round-trips and
//! the packed `Program` container's save → load → byte-identical re-save
//! guarantee over the whole model zoo.

use shortcutfusion::compiler::{CompileError, Compiler};
use shortcutfusion::config::AccelConfig;
use shortcutfusion::isa::{decode, encode, WORDS_PER_INSTR};
use shortcutfusion::program::format::{fnv1a32, unwrap as unwrap_container};
use shortcutfusion::program::Program;
use shortcutfusion::testutil::{forall, random_instruction};
use shortcutfusion::zoo;

#[test]
fn encode_decode_roundtrip_over_randomized_instructions() {
    forall("encode∘decode = id over the instruction space", 2000, |rng| {
        let i = random_instruction(rng);
        let words = encode(&i);
        assert_eq!(words.len(), WORDS_PER_INSTR);
        assert_eq!(decode(&words).unwrap(), i);
    });
}

#[test]
fn decode_never_panics_on_random_words() {
    // decode must reject or accept — never panic — whatever 11 words it
    // is handed (a corrupted stream reaches it before any checksum in
    // unit-level use).
    forall("decode is total", 2000, |rng| {
        let mut words = [0u32; WORDS_PER_INSTR];
        for w in words.iter_mut() {
            *w = rng.next_u64() as u32;
        }
        let _ = decode(&words);
    });
}

#[test]
fn program_save_load_resave_is_byte_identical_for_every_zoo_model() {
    let compiler = Compiler::new(AccelConfig::kcu1500_int8());
    for &name in zoo::MODEL_NAMES {
        let g = zoo::by_name(name, zoo::default_input(name)).unwrap();
        let analyzed = compiler.analyze(&g).unwrap();
        let lowered = compiler
            .lower(&compiler.allocate(&compiler.optimize(&analyzed).unwrap()).unwrap())
            .unwrap();
        let program = compiler.pack(&lowered).unwrap();

        let bytes = program.to_bytes();
        let loaded = Program::from_bytes(&bytes).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(loaded.to_bytes(), bytes, "{name}: re-save is not byte-identical");

        // the loaded program is the same program, not merely equal bytes
        assert_eq!(loaded.model(), program.model(), "{name}");
        assert_eq!(loaded.strategy(), "cutpoint", "{name}");
        assert_eq!(loaded.cfg(), program.cfg(), "{name}");
        assert_eq!(loaded.stream().words, program.stream().words, "{name}");
        assert_eq!(loaded.policy(), program.policy(), "{name}");
        assert_eq!(
            loaded.grouped().groups.len(),
            program.grouped().groups.len(),
            "{name}"
        );
    }
}

#[test]
fn program_file_round_trip() {
    let compiler = Compiler::new(AccelConfig::kcu1500_int8());
    let analyzed = compiler.analyze(&zoo::tinynet()).unwrap();
    let lowered = compiler
        .lower(&compiler.allocate(&compiler.optimize(&analyzed).unwrap()).unwrap())
        .unwrap();
    let program = compiler.pack(&lowered).unwrap();

    let dir = std::env::temp_dir().join("sf_program_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("tinynet.sfp");
    program.save(&path).unwrap();
    let loaded = Program::load(&path).unwrap();
    assert_eq!(loaded.to_bytes(), program.to_bytes());
}

#[test]
fn random_payload_corruption_is_always_detected() {
    let compiler = Compiler::new(AccelConfig::kcu1500_int8());
    let analyzed = compiler.analyze(&zoo::tinynet()).unwrap();
    let lowered = compiler
        .lower(&compiler.allocate(&compiler.optimize(&analyzed).unwrap()).unwrap())
        .unwrap();
    let bytes = compiler.pack(&lowered).unwrap().to_bytes();

    forall("bit flips never load", 200, |rng| {
        let mut bad = bytes.clone();
        let pos = rng.below(bad.len());
        let bit = 1u8 << rng.below(8);
        bad[pos] ^= bit;
        match Program::from_bytes(&bad) {
            Err(_) => {}
            Ok(_) => panic!("flip of bit {bit:#x} at byte {pos} loaded successfully"),
        }
    });
}

#[test]
fn container_checksum_covers_the_whole_payload() {
    let compiler = Compiler::new(AccelConfig::kcu1500_int8());
    let analyzed = compiler.analyze(&zoo::tinynet()).unwrap();
    let lowered = compiler
        .lower(&compiler.allocate(&compiler.optimize(&analyzed).unwrap()).unwrap())
        .unwrap();
    let bytes = compiler.pack(&lowered).unwrap().to_bytes();
    let payload = unwrap_container(&bytes).unwrap();
    // header stores fnv1a32(payload); recompute independently
    let stored = u32::from_le_bytes(bytes[12..16].try_into().unwrap());
    assert_eq!(stored, fnv1a32(payload));
}

#[test]
fn cross_config_pack_is_rejected() {
    let a = Compiler::new(AccelConfig::kcu1500_int8());
    let b = Compiler::new(AccelConfig::table2_int16());
    let analyzed = a.analyze(&zoo::tinynet()).unwrap();
    let lowered = a
        .lower(&a.allocate(&a.optimize(&analyzed).unwrap()).unwrap())
        .unwrap();
    assert!(matches!(b.pack(&lowered), Err(CompileError::StageMismatch(_))));
}
