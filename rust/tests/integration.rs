//! Integration tests: cross-module flows (graph → analyzer → optimizer →
//! alloc → ISA → funcsim) without the PJRT runtime (that path is covered
//! by `pipeline_e2e.rs` and `examples/e2e_verify.rs`).

use shortcutfusion::alloc::{allocate, layout};
use shortcutfusion::analyzer::analyze;
use shortcutfusion::config::AccelConfig;
use shortcutfusion::compiler::Compiler;
use shortcutfusion::funcsim::{execute, Params, Tensor};
use shortcutfusion::graph::Shape;
use shortcutfusion::isa::{decode, ReuseMode, WORDS_PER_INSTR};
use shortcutfusion::optimizer::Optimizer;
use shortcutfusion::serialize::{graph_from_json, graph_to_json};
use shortcutfusion::testutil::Rng;
use shortcutfusion::zoo;

#[test]
fn frozen_json_through_full_pipeline() {
    // export → reimport → compile must equal compiling the original
    let g = zoo::resnet50(224);
    let g2 = graph_from_json(&graph_to_json(&g)).unwrap();
    let cfg = AccelConfig::kcu1500_int8();
    let compiler = Compiler::new(cfg);
    let r1 = compiler.compile(&g).unwrap();
    let r2 = compiler.compile(&g2).unwrap();
    assert_eq!(r1.timing.total_cycles, r2.timing.total_cycles);
    assert_eq!(r1.evaluation.dram.total, r2.evaluation.dram.total);
    assert_eq!(r1.stream.words, r2.stream.words);
}

#[test]
fn instruction_stream_decodes_and_matches_groups() {
    let cfg = AccelConfig::kcu1500_int8();
    for name in ["yolov3", "efficientnet-b1"] {
        let g = zoo::by_name(name, zoo::default_input(name)).unwrap();
        let r = Compiler::new(cfg.clone()).compile(&g).unwrap();
        for (i, gr) in r.grouped.groups.iter().enumerate() {
            let chunk: [u32; WORDS_PER_INSTR] = r.stream.words
                [i * WORDS_PER_INSTR..(i + 1) * WORDS_PER_INSTR]
                .try_into()
                .unwrap();
            let ins = decode(&chunk).unwrap();
            assert_eq!(ins.group as usize, gr.id.0, "{name}");
            assert_eq!(ins.out_c as usize, gr.out_shape.c, "{name}");
            assert_eq!(ins.fused_eltwise, gr.shortcut_of.is_some(), "{name}");
        }
    }
}

#[test]
fn optimized_policy_respects_block_boundaries() {
    let cfg = AccelConfig::kcu1500_int8();
    let g = zoo::resnet152(256);
    let gg = analyze(&g);
    let opt = Optimizer::new(&gg, &cfg);
    let best = opt.optimize();
    for b in &opt.blocks {
        let first = best.policy[b.start];
        for gi in b.groups() {
            assert_eq!(best.policy[gi], first, "block {}..{} mixes modes", b.start, b.end);
        }
    }
}

#[test]
fn funcsim_runs_the_optimized_tinynet_stream() {
    // full compile of TinyNet + funcsim execution over random params
    let cfg = AccelConfig::kcu1500_int8();
    let g = zoo::tinynet();
    let r = Compiler::new(cfg.clone()).compile(&g).unwrap();
    let params = Params::random(&r.grouped, 11);
    let mut rng = Rng::from_seed(12);
    let input = Tensor::from_vec(zoo::TINYNET_INPUT, rng.i8_vec(zoo::TINYNET_INPUT.numel()));
    let values = execute(&r.grouped, &r.stream, &params, &input).unwrap();
    let fc = r.grouped.graph.find("fc").unwrap();
    assert_eq!(values[fc.0].shape, Shape::vec(10));
}

#[test]
fn dram_layout_consistent_with_placements() {
    let cfg = AccelConfig::kcu1500_int8();
    let g = zoo::yolov3(416);
    let gg = analyze(&g);
    let policy = vec![ReuseMode::Row; gg.groups.len()];
    let alloc = allocate(&gg, &policy, &cfg);
    let lay = layout(&gg, &policy, &alloc, &cfg);
    // every DRAM-resident fmap got a region
    for (gi, a) in alloc.assigns.iter().enumerate().skip(1) {
        let is_fmap = gg.groups[gi].out_shape.h * gg.groups[gi].out_shape.w > 1;
        if is_fmap
            && (a.out_loc == shortcutfusion::alloc::Loc::Dram || a.also_dram)
            && gg.groups[gi].kind != shortcutfusion::analyzer::GroupKind::Input
        {
            assert!(lay.fmaps[gi].bytes > 0, "group {gi} lacks a DRAM region");
        }
    }
    // regions sit after the weight arena
    let w_end = lay.input.offset;
    for f in lay.fmaps.iter().filter(|f| f.bytes > 0) {
        assert!(f.offset >= w_end);
    }
}

#[test]
fn sixteen_bit_mode_consistency() {
    // Table II config must flow end to end as well.
    let cfg = AccelConfig::table2_int16();
    let r = Compiler::new(cfg.clone()).compile(&zoo::resnet152(224)).unwrap();
    assert!(r.evaluation.feasible);
    assert!(r.latency_ms() > 10.0 && r.latency_ms() < 80.0, "{}", r.latency_ms());
    // weights at 2 bytes
    let wmb = r.grouped.graph.total_weight_bytes(2) as f64 / 1e6;
    assert!((wmb - 120.0).abs() < 8.0, "{wmb}");
}

#[test]
fn concat_only_and_plain_networks_compile() {
    // plain (no shortcut at all) and concat-heavy nets must not trip the
    // allocator or the segmenter
    let cfg = AccelConfig::kcu1500_int8();
    for name in ["vgg16-conv", "yolov2", "efficientdet-d0"] {
        let g = zoo::by_name(name, zoo::default_input(name)).unwrap();
        let r = Compiler::new(cfg.clone()).compile(&g).unwrap();
        assert!(r.latency_ms() > 0.0, "{name}");
    }
}
