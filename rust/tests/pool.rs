//! Buffer-pool acceptance tests: multi-tenant model-zoo serving on a
//! device-DRAM budget smaller than the combined weight footprint.
//!
//! The load-bearing properties:
//! (a) with pool capacity < Σ(program footprints), every request still
//!     completes and outputs are **bit-identical** to the unpooled
//!     `ReferenceBackend`;
//! (b) a pinned segment survives arbitrary serving pressure (the pool
//!     over-commits instead of evicting it);
//! (c) refcounts balance under concurrent serving — afterwards every
//!     resident segment is evictable again;
//! (d) policy crossover: on scan-heavy workloads the scan-resistant
//!     segmented LRU keeps a hot set that plain LRU loses;
//! (e) a sharded chain composes over the pooled backend — per-stage
//!     cold-load costs sum and stats forward through the chain.

use std::sync::Arc;

use shortcutfusion::compiler::{strategy, Compiler};
use shortcutfusion::config::AccelConfig;
use shortcutfusion::engine::{
    EngineConfig, ExecutionBackend, InferenceEngine, ReferenceBackend, ShardedBackend,
    VirtualAccelBackend,
};
use shortcutfusion::funcsim::{Params, Tensor};
use shortcutfusion::pool::{
    policy_by_name, BufferPool, PoolConfig, PoolStats, PooledBackend, SegmentId,
};
use shortcutfusion::program::Program;
use shortcutfusion::shard::{LinkModel, Partitioner};
use shortcutfusion::testutil::{forall, Rng};
use shortcutfusion::zoo;

fn cfg() -> AccelConfig {
    AccelConfig::kcu1500_int8()
}

/// Pack tinynet under a named reuse strategy — distinct strategies give
/// distinct program fingerprints, i.e. distinct pool segments.
fn pack_with(strategy_name: &str, params: Option<&Params>) -> Program {
    let graph = zoo::tinynet();
    let mut compiler =
        Compiler::with_strategy(cfg(), strategy::by_name(strategy_name).unwrap().into());
    let analyzed = compiler.analyze(&graph).unwrap();
    if let Some(p) = params {
        compiler = compiler.with_params(p.clone());
    }
    let lowered = compiler
        .lower(&compiler.allocate(&compiler.optimize(&analyzed).unwrap()).unwrap())
        .unwrap();
    compiler.pack(&lowered).unwrap()
}

fn random_input(shape: shortcutfusion::graph::Shape, seed: u64) -> Tensor {
    let mut rng = Rng::from_seed(seed);
    Tensor::from_vec(shape, rng.i8_vec(shape.numel()))
}

/// (a) pool capacity holds either program alone but never both: every
/// tenant switch pages, yet outputs stay bit-identical to unpooled runs.
#[test]
fn paging_under_pressure_is_bit_identical_to_unpooled_reference() {
    let graph = zoo::tinynet();
    let grouped = Compiler::new(cfg()).analyze(&graph).unwrap().grouped;
    let params = Params::random(&grouped, 11);
    let programs: Vec<Arc<Program>> = ["cutpoint", "fixed-frame"]
        .iter()
        .map(|s| Arc::new(pack_with(s, Some(&params))))
        .collect();
    let capacity = programs.iter().map(|p| p.resident_bytes()).max().unwrap();
    assert!(
        capacity < programs.iter().map(|p| p.resident_bytes()).sum(),
        "pool must be smaller than the combined footprint"
    );

    let pool = Arc::new(
        BufferPool::new(PoolConfig::new(capacity), policy_by_name("lru").unwrap()).unwrap(),
    );
    let engines: Vec<InferenceEngine> = programs
        .iter()
        .enumerate()
        .map(|(i, p)| {
            InferenceEngine::new(
                p.clone(),
                Arc::new(PooledBackend::new(
                    Arc::new(ReferenceBackend),
                    pool.clone(),
                    format!("tenant{i}"),
                )),
                EngineConfig {
                    workers: 1,
                    queue_capacity: 4,
                    max_batch: 1,
                    ..EngineConfig::default()
                },
            )
        })
        .collect();

    let rounds = 3u64;
    for round in 0..rounds {
        for (mi, engine) in engines.iter().enumerate() {
            let input = random_input(programs[mi].input_shape(), round * 10 + mi as u64);
            let done = engine.submit(input.clone()).unwrap().wait().unwrap();
            assert!(
                done.result.cold_load_ms.unwrap() > 0.0,
                "strict alternation on a one-program pool must always miss"
            );
            let want = ReferenceBackend.run(&programs[mi], &input).unwrap();
            assert_eq!(
                done.result.output, want.output,
                "pooled serving diverged from the unpooled reference"
            );
        }
    }
    for e in engines {
        let s = e.shutdown();
        assert_eq!((s.completed, s.failed), (rounds, 0));
    }
    let s = pool.stats();
    assert_eq!(s.hits, 0);
    assert_eq!(s.misses, 2 * rounds);
    assert_eq!(s.evictions, 2 * rounds - 1, "every insert after the first evicts");
    assert!(s.cold_load_p50_ms > 0.0);
}

/// (b) a held pin survives serving pressure: the pool over-commits
/// rather than evicting the pinned segment.
#[test]
fn pinned_program_is_never_evicted_by_serving_pressure() {
    let a = Arc::new(pack_with("cutpoint", None));
    let b = Arc::new(pack_with("fixed-frame", None));
    let capacity = a.resident_bytes().max(b.resident_bytes());
    let pool = Arc::new(
        BufferPool::new(PoolConfig::new(capacity), policy_by_name("clock").unwrap()).unwrap(),
    );

    let seg_a = PooledBackend::segment_of(&a);
    let guard = pool.pin(seg_a, a.resident_bytes(), "tenant-a");
    assert!(!guard.bypassed());

    let engine = InferenceEngine::new(
        b.clone(),
        Arc::new(PooledBackend::new(Arc::new(VirtualAccelBackend), pool.clone(), "tenant-b")),
        EngineConfig { workers: 2, queue_capacity: 8, max_batch: 2, ..EngineConfig::default() },
    );
    let pending: Vec<_> = (0..8)
        .map(|_| engine.submit(Tensor::zeros(b.input_shape())).unwrap())
        .collect();
    for p in pending {
        p.wait().unwrap();
    }
    let stats = engine.shutdown();
    assert_eq!((stats.completed, stats.failed), (8, 0));

    let s = pool.stats();
    assert!(pool.contains(seg_a), "the pinned segment must survive the pressure");
    assert!(s.overcommits > 0, "capacity pressure had to over-commit, not evict");
    drop(guard);
}

/// (c) refcounts balance under concurrent serving: once the engines shut
/// down, a capacity-sized pin can evict every previously-resident
/// segment without over-committing.
#[test]
fn refcounts_balance_under_concurrent_serving() {
    let a = Arc::new(pack_with("cutpoint", None));
    let b = Arc::new(pack_with("fixed-frame", None));
    let capacity = a.resident_bytes() + b.resident_bytes();
    let pool = Arc::new(
        BufferPool::new(PoolConfig::new(capacity), policy_by_name("slru").unwrap()).unwrap(),
    );
    let engines: Vec<InferenceEngine> = [&a, &b]
        .iter()
        .enumerate()
        .map(|(i, p)| {
            InferenceEngine::new(
                (*p).clone(),
                Arc::new(PooledBackend::new(
                    Arc::new(VirtualAccelBackend),
                    pool.clone(),
                    format!("tenant{i}"),
                )),
                EngineConfig {
                    workers: 2,
                    queue_capacity: 16,
                    max_batch: 4,
                    ..EngineConfig::default()
                },
            )
        })
        .collect();
    // both engines in flight at once: pins on the shared pool interleave
    let pending: Vec<_> = (0..16)
        .flat_map(|_| {
            engines
                .iter()
                .zip([&a, &b])
                .map(|(e, p)| e.submit(Tensor::zeros(p.input_shape())).unwrap())
                .collect::<Vec<_>>()
        })
        .collect();
    for p in pending {
        p.wait().unwrap();
    }
    for e in engines {
        assert_eq!(e.shutdown().failed, 0);
    }

    let before = pool.stats();
    // a fresh pin of the whole capacity must be able to evict everything:
    // if any serving pin leaked, eviction stalls and this over-commits
    let drain = pool.pin(SegmentId(0xDEAD_BEEF), capacity, "drain");
    assert!(!drain.bypassed());
    assert!(!pool.contains(PooledBackend::segment_of(&a)));
    assert!(!pool.contains(PooledBackend::segment_of(&b)));
    assert_eq!(pool.stats().overcommits, before.overcommits, "a pin leaked");
}

/// Replay a synthetic segment trace (unit = 1 byte) through a 4-slot
/// pool under the named policy.
fn replay(policy: &str, trace: &[u64]) -> PoolStats {
    let pool =
        BufferPool::new(PoolConfig::new(4), policy_by_name(policy).unwrap()).unwrap();
    for &seg in trace {
        pool.pin(SegmentId(seg), 1, "t");
    }
    pool.stats()
}

/// A hot set touched twice per round, then a scan of fresh segments
/// longer than the pool — the access pattern of a zoo with a popular
/// model and a long tail.
fn scan_trace(rounds: usize, scan_len: usize) -> Vec<u64> {
    let mut trace = Vec::new();
    let mut fresh = 1_000u64;
    for _ in 0..rounds {
        for _ in 0..2 {
            trace.extend([0u64, 1]);
        }
        for _ in 0..scan_len {
            trace.push(fresh);
            fresh += 1;
        }
    }
    trace
}

/// (d) measured policy crossover: segmented LRU beats plain LRU on the
/// hot-set + scan workload (strictly), and never does worse across
/// randomly sized variants of it.
#[test]
fn segmented_lru_beats_lru_on_scans() {
    let trace = scan_trace(4, 10);
    let (slru, lru) = (replay("slru", &trace), replay("lru", &trace));
    assert!(
        slru.hits > lru.hits,
        "expected a strict crossover: slru {} hits vs lru {} on {} accesses",
        slru.hits,
        lru.hits,
        trace.len()
    );
    // LRU loses the hot set to every scan: it can only hit inside the
    // double-touch itself; SLRU promotes the hot pair into the protected
    // segment where scans cannot reach it
    assert_eq!(slru.hits + slru.misses, lru.hits + lru.misses);

    forall("slru >= lru on scan-heavy traces", 32, |rng| {
        let trace = scan_trace(rng.range(2, 6), rng.range(5, 16));
        assert!(replay("slru", &trace).hits >= replay("lru", &trace).hits);
    });
}

/// (e) a 2-shard reference chain over the pooled backend: bit-identical
/// to the unsharded funcsim, per-stage cold loads summed, stats
/// forwarded through the chain.
#[test]
fn sharded_chain_composes_over_the_pooled_backend() {
    let graph = zoo::tinynet();
    let grouped = Compiler::new(cfg()).analyze(&graph).unwrap().grouped;
    let params = Params::random(&grouped, 11);

    let full = pack_with("cutpoint", Some(&params));
    let input = random_input(full.input_shape(), 3);
    let want = ReferenceBackend.run(&full, &input).unwrap().output.unwrap();

    let plan = Partitioner::homogeneous(cfg(), 2)
        .unwrap()
        .with_link(LinkModel::pcie_gen3())
        .plan(&graph)
        .unwrap();
    let shards: Vec<Arc<Program>> =
        plan.pack_with_params(Some(&params)).unwrap().into_iter().map(Arc::new).collect();
    let combined: u64 = shards.iter().map(|p| p.resident_bytes()).sum();

    let pool = Arc::new(
        BufferPool::new(PoolConfig::new(combined), policy_by_name("lru").unwrap()).unwrap(),
    );
    let chain = ShardedBackend::new(
        shards,
        Arc::new(PooledBackend::new(Arc::new(ReferenceBackend), pool, "shards")),
        LinkModel::pcie_gen3(),
    )
    .unwrap();
    let front = chain.front().clone();

    let cold = chain.run(&front, &input).unwrap();
    assert_eq!(cold.output.unwrap(), want, "pooled sharded chain diverged");
    assert!(cold.cold_load_ms.unwrap() > 0.0, "both stages paged in");
    let warm = chain.run(&front, &input).unwrap();
    assert_eq!(warm.cold_load_ms, Some(0.0), "both stages resident");
    assert_eq!(warm.output.unwrap(), want);

    let s = chain.pool_stats().expect("stats forward through the chain");
    assert_eq!((s.hits, s.misses, s.evictions), (2, 2, 0));
}
