#!/usr/bin/env python3
"""Diff two BENCH_*.json snapshots and fail on regressions.

Usage:
    bench_diff.py BASELINE CURRENT [--threshold 0.10] [--ignore REGEX]

Walks both JSON documents, collects every numeric leaf under a dotted
path (list indices become path segments), and compares the values that
exist on both sides.  A leaf whose relative change exceeds the
threshold is a regression; a baseline leaf missing from the current
snapshot is one too (a silently dropped metric is how trajectories rot).
Leaves whose path matches --ignore are skipped — use it for wall-clock
metrics (p50/p95, throughput) that are noise on shared CI runners,
while the modeled numbers (DRAM bytes, SRAM bytes, analytical latency)
stay strict.

Only the standard library is used: the repo builds with no crates.io or
PyPI access, and this script honours the same constraint.
"""

import argparse
import json
import re
import sys


def leaves(node, prefix=""):
    """Yield (dotted_path, value) for every numeric leaf under node."""
    if isinstance(node, bool):
        return
    if isinstance(node, (int, float)):
        yield prefix, float(node)
    elif isinstance(node, dict):
        for key in sorted(node):
            yield from leaves(node[key], f"{prefix}.{key}" if prefix else str(key))
    elif isinstance(node, list):
        for i, item in enumerate(node):
            yield from leaves(item, f"{prefix}[{i}]")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline")
    ap.add_argument("current")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="max allowed relative change per metric (default 0.10)")
    ap.add_argument("--ignore", default=None,
                    help="regex of metric paths to skip (noisy wall-clock stats)")
    args = ap.parse_args()

    with open(args.baseline) as f:
        base = dict(leaves(json.load(f)))
    with open(args.current) as f:
        cur = dict(leaves(json.load(f)))

    skip = re.compile(args.ignore) if args.ignore else None
    regressions = []
    checked = 0
    for path, old in sorted(base.items()):
        if skip and skip.search(path):
            continue
        if path not in cur:
            regressions.append(f"{path}: present in baseline, missing now")
            continue
        checked += 1
        new = cur[path]
        if old == new:
            continue
        rel = abs(new - old) / max(abs(old), 1e-12)
        if rel > args.threshold:
            regressions.append(
                f"{path}: {old:g} -> {new:g} ({rel:+.1%} > {args.threshold:.0%})")

    for line in regressions:
        print(f"REGRESSION {line}")
    print(f"bench_diff: {checked} metrics compared against {args.baseline}, "
          f"{len(regressions)} regression(s)")
    return 1 if regressions else 0


if __name__ == "__main__":
    sys.exit(main())
