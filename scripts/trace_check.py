#!/usr/bin/env python3
"""Validate a Chrome trace-event JSON file exported by --trace-out.

Usage:
    trace_check.py TRACE.json [--min-events N]

Checks the structural contract chrome://tracing / Perfetto rely on:

* the document is an object with ``displayTimeUnit`` and a non-empty
  ``traceEvents`` list;
* every event carries ``name``, ``cat``, ``ph``, ``ts``, ``pid`` and
  ``tid``, with ``ph`` one of ``X`` (complete span, requires a
  non-negative ``dur``) or ``i`` (instant, requires scope ``s``);
* timestamps are non-negative and non-decreasing in file order — the
  exporter sorts before serialising, so an out-of-order event means the
  export path broke.

Only the standard library is used: the repo builds with no crates.io or
PyPI access, and this script honours the same constraint.
"""

import argparse
import json
import sys

SPAN, INSTANT = "X", "i"


def fail(msg):
    print(f"trace_check: {msg}", file=sys.stderr)
    sys.exit(1)


def check(doc, min_events):
    if not isinstance(doc, dict):
        fail("top level must be a JSON object")
    if "displayTimeUnit" not in doc:
        fail("missing displayTimeUnit")
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        fail("traceEvents must be a list")
    if len(events) < min_events:
        fail(f"expected >= {min_events} events, found {len(events)}")

    last_ts = None
    for i, ev in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            fail(f"{where}: event must be an object")
        for key in ("name", "cat", "ph", "ts", "pid", "tid"):
            if key not in ev:
                fail(f"{where}: missing {key!r}")
        ph = ev["ph"]
        if ph not in (SPAN, INSTANT):
            fail(f"{where}: unknown phase {ph!r} (expected {SPAN!r} or {INSTANT!r})")
        ts = ev["ts"]
        if not isinstance(ts, (int, float)) or ts < 0:
            fail(f"{where}: bad ts {ts!r}")
        if last_ts is not None and ts < last_ts:
            fail(f"{where}: ts {ts} goes backwards (previous {last_ts})")
        last_ts = ts
        if ph == SPAN:
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                fail(f"{where}: span needs a non-negative dur, got {dur!r}")
        else:
            if ev.get("s") != "t":
                fail(f"{where}: instant needs thread scope s='t', got {ev.get('s')!r}")
    return len(events)


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="Chrome trace-event JSON file")
    ap.add_argument("--min-events", type=int, default=1,
                    help="minimum number of events the trace must hold")
    args = ap.parse_args()
    try:
        with open(args.trace) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        fail(f"{args.trace}: {e}")
    n = check(doc, args.min_events)
    cats = sorted({ev["cat"] for ev in doc["traceEvents"]})
    print(f"trace_check: OK — {n} events across categories {cats}")


if __name__ == "__main__":
    main()
